//! Native Rust compute backend — `crate::math` behind the backend trait.
//!
//! The one place (besides the PJRT mirror) that dispatches on the batch
//! layout: dense batches run the row-major kernels, CSR batches the
//! nnz-proportional sparse kernels. Solvers above this line are
//! layout-blind.
//!
//! Every kernel reached from here is itself runtime-dispatched through the
//! [`crate::math::simd::KernelSet`] table (AVX2 / NEON / portable scalar,
//! resolved once per process), so this backend never names an instruction
//! set — and [`kernel_set`](NativeBackend::kernel_set) reports which table
//! is live for bench labels and logs.

use crate::backend::ComputeBackend;
use crate::data::batch::BatchView;
use crate::error::Result;

/// Allocation-free native backend.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// Construct the native backend.
    pub fn new() -> Self {
        NativeBackend
    }

    /// Name of the kernel table this backend's math runs on (`"scalar"`,
    /// `"avx2"`, or `"neon"`), resolved by [`crate::math::simd::active`].
    pub fn kernel_set(&self) -> &'static str {
        crate::math::simd::active_name()
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn is_native_host(&self) -> bool {
        true
    }

    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &BatchView<'_>,
        c: f32,
        out: &mut [f32],
    ) -> Result<()> {
        crate::math::grad_into_view(w, batch, c, out);
        Ok(())
    }

    fn batch_obj(&mut self, w: &[f32], batch: &BatchView<'_>, c: f32) -> Result<f64> {
        Ok(match batch {
            BatchView::Dense(d) => crate::math::objective_batch(w, d.x, d.y, d.cols, c),
            BatchView::Csr(s) => crate::math::sparse::objective_batch_csr(w, s, c),
        })
    }

    fn loss_sum(&mut self, w: &[f32], batch: &BatchView<'_>) -> Result<f64> {
        Ok(crate::math::loss_sum_view(w, batch))
    }

    /// Pooled full objective: same chunk geometry and fold order as the
    /// serial default (bit-identical for any pool size), but the chunk
    /// loss sums run on the persistent worker pool.
    fn full_objective(&mut self, w: &[f32], ds: &crate::data::Dataset, c: f32) -> Result<f64> {
        crate::math::chunked::full_objective(w, ds, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::csr::CsrDataset;
    use crate::data::Dataset;
    use crate::rng::Rng;

    fn toy(rows: usize, cols: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from(1);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        (x, y, w)
    }

    #[test]
    fn matches_math_module() {
        let (x, y, w) = toy(32, 8);
        let view = BatchView::dense(&x, &y, 8);
        let mut be = NativeBackend::new();
        let mut g = vec![0f32; 8];
        be.grad_into(&w, &view, 0.1, &mut g).unwrap();
        let mut want = vec![0f32; 8];
        crate::math::grad_into(&w, &x, &y, 8, 0.1, &mut want);
        assert_eq!(g, want);
        assert_eq!(
            be.batch_obj(&w, &view, 0.1).unwrap(),
            crate::math::objective_batch(&w, &x, &y, 8, 0.1)
        );
    }

    #[test]
    fn csr_batches_dispatch_to_sparse_kernels() {
        let (x, y, w) = toy(24, 6);
        let dense = crate::data::dense::DenseDataset::new("t", 6, x.clone(), y.clone()).unwrap();
        let csr = CsrDataset::from_dense(&dense).unwrap();
        let mut be = NativeBackend::new();
        let dv = BatchView::dense(&x, &y, 6);
        let sv = BatchView::Csr(csr.slice(0, 24));
        let mut gd = vec![0f32; 6];
        let mut gs = vec![0f32; 6];
        be.grad_into(&w, &dv, 0.2, &mut gd).unwrap();
        be.grad_into(&w, &sv, 0.2, &mut gs).unwrap();
        for k in 0..6 {
            assert!((gd[k] - gs[k]).abs() < 1e-5);
        }
        let od = be.batch_obj(&w, &dv, 0.2).unwrap();
        let os = be.batch_obj(&w, &sv, 0.2).unwrap();
        assert!((od - os).abs() < 1e-5 * (1.0 + od.abs()));
    }

    #[test]
    fn full_objective_equals_single_batch_objective() {
        let (x, y, w) = toy(100, 5);
        let ds: Dataset =
            crate::data::dense::DenseDataset::new("t", 5, x.clone(), y.clone()).unwrap().into();
        let mut be = NativeBackend::new();
        let full = be.full_objective(&w, &ds, 0.2).unwrap();
        let whole = crate::math::objective_full(&w, &x, &y, 5, 0.2);
        assert!((full - whole).abs() < 1e-9, "{full} vs {whole}");
    }

    #[test]
    fn full_objective_layouts_agree() {
        let (x, y, w) = toy(90, 7);
        let dense = crate::data::dense::DenseDataset::new("t", 7, x, y).unwrap();
        let csr = CsrDataset::from_dense(&dense).unwrap();
        let mut be = NativeBackend::new();
        let a = be.full_objective(&w, &dense.into(), 0.05).unwrap();
        let b = be.full_objective(&w, &Dataset::Csr(csr), 0.05).unwrap();
        assert!((a - b).abs() < 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
    }

    #[test]
    fn kernel_set_reports_active_table() {
        let be = NativeBackend::new();
        let name = be.kernel_set();
        assert!(["scalar", "avx2", "neon"].contains(&name), "{name}");
    }

    #[test]
    fn fused_unsupported() {
        let (x, y, mut w) = toy(8, 3);
        let view = BatchView::dense(&x, &y, 3);
        let mut be = NativeBackend::new();
        let handled = be
            .fused(
                crate::backend::FusedStep::Mbsgd { w: &mut w, lr: 0.1 },
                &view,
                0.0,
            )
            .unwrap();
        assert!(!handled);
    }
}
