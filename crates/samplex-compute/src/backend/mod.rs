//! Compute backends: where the per-iteration ERM math runs.
//!
//! * [`NativeBackend`] — hand-rolled Rust hot loop (`crate::math`), the
//!   portable fallback and cross-check oracle.
//! * [`PjrtBackend`] — executes the AOT-compiled Layer-2 JAX/Pallas modules
//!   through the PJRT C API (`crate::runtime`); the production path.
//!
//! Solvers call [`ComputeBackend::grad_into`] / [`ComputeBackend::batch_obj`]
//! and do their O(n) state algebra in Rust. Backends that can fuse a whole
//! solver update into one device call (PJRT, via the `mbsgd`/`sag`/`saga`/
//! `svrg`/`saag2` artifacts) advertise it through [`ComputeBackend::fused`],
//! which the solvers try first — one call per inner iteration instead of
//! gradient + host algebra.

pub mod native;
pub mod pjrt;

use crate::data::batch::BatchView;
use crate::error::Result;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

/// A fused solver-step request (state slices owned by the solver).
#[derive(Debug)]
pub enum FusedStep<'a> {
    /// `w -= lr * g(w)`.
    Mbsgd { w: &'a mut [f32], lr: f32 },
    /// SAG: `avg += (g - yj)/m; yj = g; w -= lr*avg`.
    Sag { w: &'a mut [f32], yj: &'a mut [f32], avg: &'a mut [f32], lr: f32, inv_m: f32 },
    /// SAGA: `w -= lr*(g - yj + avg); avg += (g - yj)/m; yj = g`.
    Saga { w: &'a mut [f32], yj: &'a mut [f32], avg: &'a mut [f32], lr: f32, inv_m: f32 },
    /// SVRG inner: `w -= lr*(g(w) - g(w_snap) + mu)`.
    Svrg { w: &'a mut [f32], w_snap: &'a [f32], mu: &'a [f32], lr: f32 },
    /// SAAG-II: `d = acc/m + coeff*g; acc += g; w -= lr*d`.
    Saag2 { w: &'a mut [f32], acc: &'a mut [f32], lr: f32, coeff: f32, inv_m: f32 },
}

/// Per-iteration compute interface shared by all solvers.
pub trait ComputeBackend {
    /// Backend label for reports ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// Mini-batch gradient of eq.(3) into `out` (length = cols).
    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &BatchView<'_>,
        c: f32,
        out: &mut [f32],
    ) -> Result<()>;

    /// Mini-batch objective of eq.(3) (mean loss + (C/2)||w||²) — what the
    /// backtracking line search evaluates.
    fn batch_obj(&mut self, w: &[f32], batch: &BatchView<'_>, c: f32) -> Result<f64>;

    /// Raw loss sum over the batch (no mean, no regularizer) — used by the
    /// chunked full-objective sweep.
    fn loss_sum(&mut self, w: &[f32], batch: &BatchView<'_>) -> Result<f64>;

    /// Try to run a whole solver update as one fused device call.
    /// `Ok(false)` means "not supported here — compose it yourself".
    fn fused(&mut self, _step: FusedStep<'_>, _batch: &BatchView<'_>, _c: f32) -> Result<bool> {
        Ok(false)
    }

    /// True when this backend's kernels *are* the crate's native host math.
    /// Solvers may then take host-side CSR fast paths (MBSGD's lazy l2)
    /// without mis-attributing work to a device backend; non-native
    /// backends keep every step on their own dispatch path (and report
    /// their own layout limits, e.g. PJRT's dense-only artifacts).
    fn is_native_host(&self) -> bool {
        false
    }

    /// Full-dataset objective of eq.(2), chunked through `loss_sum`. The
    /// chunks are zero-copy slice views for either layout.
    fn full_objective(
        &mut self,
        w: &[f32],
        ds: &crate::data::Dataset,
        c: f32,
    ) -> Result<f64> {
        let chunk = 4096.min(ds.rows());
        let mut total = 0f64;
        let mut start = 0;
        while start < ds.rows() {
            let end = (start + chunk).min(ds.rows());
            let view = ds.slice_view(start, end);
            total += self.loss_sum(w, &view)?;
            start = end;
        }
        Ok(total / ds.rows() as f64 + 0.5 * c as f64 * crate::math::nrm2_sq(w))
    }
}
