//! CLI entry point: `cargo run -p samplex-lint -- --workspace` lints every
//! workspace member's `src/` tree; explicit paths are still accepted
//! (`cargo run -p samplex-lint -- crates/samplex-data/src rust/src`).
//!
//! Prints one `file:line rule message` diagnostic per violation on
//! stdout (machine-readable, sorted), a summary on stderr, and exits
//! with 0 (clean), 1 (violations), or 2 (usage / I/O error).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args.iter().any(|a| a == "-h" || a == "--help") {
        eprintln!("usage: samplex-lint <file-or-dir>...");
        eprintln!("       samplex-lint --workspace [WORKSPACE_ROOT]");
        eprintln!(
            "rules: no-panic-plane lock-discipline determinism atomics-audit safety-comments \
             simd-dispatch io-discipline clock-discipline"
        );
        eprintln!("suppress with: // samplex-lint: allow(<rule>) -- <reason>");
        return ExitCode::from(2);
    }
    let paths: Vec<PathBuf> = if args[0] == "--workspace" {
        if args.len() > 2 {
            eprintln!("samplex-lint: --workspace takes at most one root argument");
            return ExitCode::from(2);
        }
        let root = PathBuf::from(args.get(1).map(|s| s.as_str()).unwrap_or("."));
        match samplex_lint::workspace_member_src_dirs(&root) {
            Ok(dirs) => {
                eprintln!(
                    "samplex-lint: linting {} workspace member src tree(s) under {}",
                    dirs.len(),
                    root.display()
                );
                dirs
            }
            Err(e) => {
                eprintln!("samplex-lint: cannot resolve workspace at {}: {e}", root.display());
                return ExitCode::from(2);
            }
        }
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    for p in &paths {
        if !p.exists() {
            eprintln!("samplex-lint: path not found: {}", p.display());
            return ExitCode::from(2);
        }
    }
    match samplex_lint::lint_paths(&paths) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                eprintln!("samplex-lint: clean");
                ExitCode::SUCCESS
            } else {
                eprintln!("samplex-lint: {} violation(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("samplex-lint: i/o error: {e}");
            ExitCode::from(2)
        }
    }
}
