//! CLI entry point: `cargo run -p samplex-lint -- rust/src`.
//!
//! Prints one `file:line rule message` diagnostic per violation on
//! stdout (machine-readable, sorted), a summary on stderr, and exits
//! with 0 (clean), 1 (violations), or 2 (usage / I/O error).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args.iter().any(|a| a == "-h" || a == "--help") {
        eprintln!("usage: samplex-lint <file-or-dir>...");
        eprintln!(
            "rules: no-panic-plane lock-discipline determinism atomics-audit safety-comments \
             simd-dispatch io-discipline clock-discipline"
        );
        eprintln!("suppress with: // samplex-lint: allow(<rule>) -- <reason>");
        return ExitCode::from(2);
    }
    let paths: Vec<PathBuf> = args.iter().map(PathBuf::from).collect();
    for p in &paths {
        if !p.exists() {
            eprintln!("samplex-lint: path not found: {}", p.display());
            return ExitCode::from(2);
        }
    }
    match samplex_lint::lint_paths(&paths) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                eprintln!("samplex-lint: clean");
                ExitCode::SUCCESS
            } else {
                eprintln!("samplex-lint: {} violation(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("samplex-lint: i/o error: {e}");
            ExitCode::from(2)
        }
    }
}
