//! samplex-lint: a source-level checker for the samplex invariants.
//!
//! The crate's determinism and out-of-core guarantees (bit-identical
//! trajectories across thread counts, page budgets, and readahead on/off)
//! rest on a handful of coding rules that used to live only in doc
//! comments. This tool machine-checks them:
//!
//! - **no-panic-plane** (R1): `panic!` / `.unwrap()` / `.expect(` /
//!   `unreachable!` are forbidden in data-plane modules (`data/`,
//!   `storage/`, `pipeline/`, `math/chunked.rs`) — errors must travel as
//!   typed `Error` values.
//! - **lock-discipline** (R2): in `storage/pagestore.rs`, no file
//!   seek/read or page decode while a shard lock is held, and no nested
//!   lock acquisition.
//! - **determinism** (R3): no `HashMap`/`HashSet`, `Instant::now`,
//!   `SystemTime::now`, `thread::current`, or `available_parallelism`
//!   in reduction/fold paths (`math/chunked.rs`, `train/parallel.rs`,
//!   `backend/native.rs`).
//! - **atomics-audit** (R4): every `Ordering::Relaxed` must sit on an
//!   annotated stats counter (a `relaxed-ok:` comment on the same line or
//!   on the comment block immediately above a contiguous run of Relaxed
//!   lines) — never on a flag another thread observes for
//!   synchronization.
//! - **safety-comments** (R5): every `unsafe` token must carry a
//!   `// SAFETY:` comment (same line or the comment block directly
//!   above).
//! - **simd-dispatch** (R6): `#[target_feature]` functions are defined
//!   only under `math/simd/`, and no file outside `math/simd/` calls one
//!   directly — arch kernels are reachable solely through the dispatched
//!   `KernelSet` function table. This is the one cross-file rule: pass 1
//!   collects every `#[target_feature]` function name in the linted set,
//!   pass 2 flags out-of-module definitions and direct calls.
//! - **io-discipline** (R7): raw `.read_exact(` / `.seek(` calls are
//!   forbidden in `storage/` modules outside `storage/retry.rs` — every
//!   byte pulled off disk must pass through the bounded-retry + checksum
//!   recovery wrapper (`retry::read_exact_at`), so transient faults,
//!   deadlines and corruption are handled in exactly one place.
//! - **clock-discipline** (R8): raw `Instant::now` / `SystemTime::now`
//!   reads are allowed only under a `metrics/` or `obs/` directory —
//!   everything else measures time through the
//!   `metrics::timer::monotonic_ns` seam (or not at all), so there is
//!   one clock, spans from every thread share one origin, and wall-clock
//!   can never silently leak into a deterministic plane.
//!
//! Violations are suppressible only via an explicit
//! `// samplex-lint: allow(<rule>) -- <reason>` annotation on the same
//! line or the line directly above; each annotation suppresses exactly
//! one finding. Malformed annotations are reported as `bad-allow`,
//! annotations that suppress nothing as `unused-allow`.
//!
//! The scanner is deliberately a hand-rolled line/token pass (no syn, no
//! proc-macro, zero dependencies): it strips strings, char literals, and
//! comments, masks `#[cfg(test)]` items, and then applies per-line token
//! rules plus a brace-depth lock-scope tracker for R2. It is a
//! conservative approximation of Rust syntax, not a parser — which is
//! exactly enough for the invariants above and keeps the tool buildable
//! offline anywhere the main crate builds.

use std::path::{Path, PathBuf};

/// The named rules. `BadAllow`/`UnusedAllow` are meta-diagnostics about
/// the annotation mechanism itself and cannot be allowed away.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// R1: no panicking constructs in data-plane modules.
    NoPanicPlane,
    /// R2: no file I/O or decode under a shard lock; no nested locks.
    LockDiscipline,
    /// R3: no nondeterministic values feeding reduction/fold paths.
    Determinism,
    /// R4: `Ordering::Relaxed` only on annotated stats counters.
    AtomicsAudit,
    /// R5: every `unsafe` carries a `// SAFETY:` justification.
    SafetyComments,
    /// R6: `#[target_feature]` kernels live in `math/simd/` and are
    /// reached only through the dispatched `KernelSet` table.
    SimdDispatch,
    /// R7: raw file reads in `storage/` only inside the retry wrapper.
    IoDiscipline,
    /// R8: raw clock reads only under `metrics/` / `obs/` directories.
    ClockDiscipline,
    /// Meta: malformed `samplex-lint:` annotation.
    BadAllow,
    /// Meta: an allow annotation that suppressed nothing.
    UnusedAllow,
}

impl Rule {
    /// Stable machine-readable rule name, as printed in diagnostics.
    pub fn name(self) -> &'static str {
        match self {
            Rule::NoPanicPlane => "no-panic-plane",
            Rule::LockDiscipline => "lock-discipline",
            Rule::Determinism => "determinism",
            Rule::AtomicsAudit => "atomics-audit",
            Rule::SafetyComments => "safety-comments",
            Rule::SimdDispatch => "simd-dispatch",
            Rule::IoDiscipline => "io-discipline",
            Rule::ClockDiscipline => "clock-discipline",
            Rule::BadAllow => "bad-allow",
            Rule::UnusedAllow => "unused-allow",
        }
    }

    /// Parse an allow-able rule name (the meta rules are not allowed
    /// targets).
    pub fn from_name(s: &str) -> Option<Rule> {
        match s {
            "no-panic-plane" => Some(Rule::NoPanicPlane),
            "lock-discipline" => Some(Rule::LockDiscipline),
            "determinism" => Some(Rule::Determinism),
            "atomics-audit" => Some(Rule::AtomicsAudit),
            "safety-comments" => Some(Rule::SafetyComments),
            "simd-dispatch" => Some(Rule::SimdDispatch),
            "io-discipline" => Some(Rule::IoDiscipline),
            "clock-discipline" => Some(Rule::ClockDiscipline),
            _ => None,
        }
    }
}

/// One diagnostic, printed as `file:line rule message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path as handed to the linter (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Violated rule.
    pub rule: Rule,
    /// Human-readable explanation.
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule.name(), self.msg)
    }
}

/// One physical source line after lexical stripping: `code` has strings
/// and char literals blanked and comments removed; `comment` holds the
/// comment text (line or block) that appeared on the line.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code text with string/char contents blanked out.
    pub code: String,
    /// Comment text carried by this line.
    pub comment: String,
}

fn is_ident_char(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Split source into per-line (code, comment) pairs. String literals
/// become `""`, char literals become `' '`, raw strings are consumed,
/// and block comments (including nested ones) are routed to `comment`.
pub fn strip_source(src: &str) -> Vec<Line> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
    }
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        let nxt = if i + 1 < n { cs[i + 1] } else { '\0' };
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if st == St::LineComment {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && nxt == '/' {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && nxt == '*' {
                    st = St::BlockComment;
                    block_depth = 1;
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push_str("\"\"");
                    i += 1;
                } else if c == 'r'
                    && (nxt == '"' || nxt == '#')
                    && (i == 0 || !is_ident_char(cs[i - 1]))
                {
                    // candidate raw string r"..." / r#"..."#
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && cs[j] == '"' {
                        st = St::RawStr;
                        raw_hashes = h;
                        cur.code.push_str("\"\"");
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime
                    if nxt == '\\' {
                        // escaped char literal: skip to the closing quote
                        let mut j = i + 2;
                        while j < n && cs[j] != '\'' {
                            j += 1;
                        }
                        cur.code.push_str("' '");
                        i = j + 1;
                    } else if i + 2 < n && nxt != '\'' && cs[i + 2] == '\'' {
                        cur.code.push_str("' '");
                        i += 3;
                    } else {
                        // lifetime marker: keep it, it is not a string
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment => {
                if c == '/' && nxt == '*' {
                    block_depth += 1;
                    i += 2;
                } else if c == '*' && nxt == '/' {
                    block_depth -= 1;
                    if block_depth == 0 {
                        st = St::Code;
                    }
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else {
                    if c == '"' {
                        st = St::Code;
                    }
                    i += 1;
                }
            }
            St::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && cs[j] == '#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        st = St::Code;
                        i = j;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// Mark the lines that belong to `#[cfg(test)]` items (the attribute
/// line, the item header, its braced body, and the closing brace). The
/// rules do not apply there: tests may unwrap freely.
pub fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut skip_above: Option<i64> = None;
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        let attr_at = code.find("#[cfg(test)]");
        let mut in_test = skip_above.is_some() || pending;
        for (pos, ch) in code.char_indices() {
            if attr_at == Some(pos) && skip_above.is_none() {
                pending = true;
                in_test = true;
            }
            match ch {
                '{' => {
                    depth += 1;
                    if pending && skip_above.is_none() {
                        skip_above = Some(depth - 1);
                        pending = false;
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(d) = skip_above {
                        if depth <= d {
                            skip_above = None;
                            in_test = true; // the closing brace is still test
                        }
                    }
                }
                ';' => {
                    // a braceless item (e.g. `#[cfg(test)] use ...;`) ends here
                    if pending && skip_above.is_none() {
                        pending = false;
                        in_test = true;
                    }
                }
                _ => {}
            }
            if skip_above.is_some() {
                in_test = true;
            }
        }
        if pending {
            in_test = true;
        }
        mask[idx] = in_test;
    }
    mask
}

/// Which rule families apply to a file, decided from its path.
#[derive(Debug, Clone, Copy, Default)]
pub struct FileClass {
    /// R1 applies: under a `data/`, `storage/`, or `pipeline/` directory,
    /// or the chunked reduction module itself.
    pub data_plane: bool,
    /// R3 applies: a reduction/fold path.
    pub determinism: bool,
    /// R2 applies: the shard-locked page store.
    pub pagestore: bool,
    /// R6 home: under `math/simd/`, where `#[target_feature]` kernels
    /// (and direct calls to them) are legitimate.
    pub simd_home: bool,
    /// R7 applies: under a `storage/` directory, except the retry
    /// wrapper module itself (`storage/retry.rs`), which is the one
    /// sanctioned home of raw file reads.
    pub storage_io: bool,
    /// R8 exempt: under a `metrics/` or `obs/` directory, the sanctioned
    /// homes of raw clock reads (the timer seam and the tracing plane).
    pub clock_exempt: bool,
}

/// Classify a path (forward or back slashes) into rule families.
/// R4 and R5 are global and need no class.
pub fn classify(path: &str) -> FileClass {
    let p = path.replace('\\', "/");
    let segs: Vec<&str> = p.split('/').collect();
    let ndirs = segs.len().saturating_sub(1);
    let dir_hit = segs
        .iter()
        .take(ndirs)
        .any(|s| *s == "data" || *s == "storage" || *s == "pipeline");
    let storage_dir = segs.iter().take(ndirs).any(|s| *s == "storage");
    let clock_home = segs.iter().take(ndirs).any(|s| *s == "metrics" || *s == "obs");
    FileClass {
        data_plane: dir_hit || p.ends_with("math/chunked.rs"),
        determinism: p.ends_with("math/chunked.rs")
            || p.ends_with("train/parallel.rs")
            || p.ends_with("backend/native.rs"),
        pagestore: p.ends_with("storage/pagestore.rs"),
        simd_home: p.contains("math/simd/"),
        storage_io: storage_dir && !p.ends_with("storage/retry.rs"),
        clock_exempt: clock_home,
    }
}

fn occurrences(hay: &str, needle: &str) -> usize {
    let mut count = 0usize;
    let mut at = 0usize;
    while let Some(p) = hay[at..].find(needle) {
        count += 1;
        at += p + needle.len();
    }
    count
}

fn has_word(hay: &str, word: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut at = 0usize;
    while let Some(p) = hay[at..].find(word) {
        let s = at + p;
        let e = s + word.len();
        let pre_ok = s == 0 || !(bytes[s - 1] == b'_' || bytes[s - 1].is_ascii_alphanumeric());
        let post_ok = e >= bytes.len() || !(bytes[e] == b'_' || bytes[e].is_ascii_alphanumeric());
        if pre_ok && post_ok {
            return true;
        }
        at = e;
    }
    false
}

/// R4 annotation: a `relaxed-ok:` marker on this line's comment, or on
/// the comment block immediately above a contiguous run of
/// `Ordering::Relaxed` lines (so one marker covers e.g. a whole stats
/// snapshot). Any other code line breaks the chain.
fn relaxed_annotated(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("relaxed-ok:") {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let l = &lines[k];
        if l.code.trim().is_empty() {
            if l.comment.trim().is_empty() {
                return false; // blank line ends the block
            }
            if l.comment.contains("relaxed-ok:") {
                return true;
            }
        } else if l.code.contains("Ordering::Relaxed") {
            if l.comment.contains("relaxed-ok:") {
                return true;
            }
        } else {
            return false;
        }
    }
    false
}

/// R5 annotation: `SAFETY:` in this line's comment or in the contiguous
/// comment-only block directly above.
fn safety_annotated(lines: &[Line], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY:") {
        return true;
    }
    let mut k = idx;
    while k > 0 {
        k -= 1;
        let l = &lines[k];
        if !l.code.trim().is_empty() || l.comment.trim().is_empty() {
            return false;
        }
        if l.comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// An open lock scope in the R2 tracker.
struct LockScope {
    kind: &'static str,
    guard: Option<String>,
    depth: i64,
}

fn lock_kind(arg: &str) -> &'static str {
    if arg.contains("file") {
        "file"
    } else if arg.contains("state") {
        "state"
    } else {
        "shard"
    }
}

const SHARD_FORBIDDEN: [&str; 4] = [".seek(", ".read_exact(", ".decode(", "read_run("];

/// Extract the binding identifier from `let [mut] ident =` directly
/// preceding a `lock_recovering(` call, if any.
fn binding_ident(before: &str) -> Option<String> {
    let t = before.trim_end().strip_suffix('=')?.trim_end();
    let ident: String = {
        let tail: Vec<char> = t.chars().rev().take_while(|c| is_ident_char(*c)).collect();
        tail.into_iter().rev().collect()
    };
    if ident.is_empty() {
        return None;
    }
    let rest = t[..t.len() - ident.len()].trim_end();
    if rest.ends_with("let") || rest.ends_with("mut") {
        Some(ident)
    } else {
        None
    }
}

/// R2: track lock scopes by brace depth in `storage/pagestore.rs`.
///
/// Locks are acquired via the file's `lock_recovering(...)` helper; the
/// argument text classifies the lock (`file`, `state`, else `shard`).
/// A `let`-bound guard lives until its block closes or `drop(guard)`;
/// an expression temporary lives for its own line. While a shard lock is
/// held, file seeks/reads, page decode, and `read_run` are forbidden;
/// while any lock is held, acquiring another is forbidden.
fn lock_discipline(file: &str, lines: &[Line], mask: &[bool]) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut depth: i64 = 0;
    let mut scopes: Vec<LockScope> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let code = &line.code;
        let ln = idx + 1;
        if !mask[idx] {
            if let Some(p) = code.find("lock_recovering(") {
                let after = &code[p + "lock_recovering(".len()..];
                let arg = after.split(')').next().unwrap_or(after);
                let kind = lock_kind(arg);
                for s in &scopes {
                    let held = match &s.guard {
                        Some(g) => format!("{} lock (guard `{g}`)", s.kind),
                        None => format!("{} lock", s.kind),
                    };
                    out.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::LockDiscipline,
                        msg: format!("acquires the {kind} lock while already holding the {held}"),
                    });
                }
                match binding_ident(&code[..p]) {
                    Some(g) => scopes.push(LockScope { kind, guard: Some(g), depth }),
                    None => {
                        // guard is a temporary: it lives for this line only
                        if kind == "shard" {
                            for tok in SHARD_FORBIDDEN {
                                if code.contains(tok) {
                                    out.push(Finding {
                                        file: file.to_string(),
                                        line: ln,
                                        rule: Rule::LockDiscipline,
                                        msg: format!(
                                            "{tok} in the same expression as a shard-lock \
                                             acquisition"
                                        ),
                                    });
                                }
                            }
                        }
                    }
                }
            } else {
                if let Some(s) = scopes.iter().find(|s| s.kind == "shard") {
                    let g = s.guard.clone().unwrap_or_default();
                    for tok in SHARD_FORBIDDEN {
                        if code.contains(tok) {
                            out.push(Finding {
                                file: file.to_string(),
                                line: ln,
                                rule: Rule::LockDiscipline,
                                msg: format!(
                                    "{tok} inside the shard-lock scope of guard `{g}` — do \
                                     file I/O and page decode outside the shard lock"
                                ),
                            });
                        }
                    }
                }
                if let Some(s) = scopes.iter().find(|s| s.kind == "file") {
                    let g = s.guard.clone().unwrap_or_default();
                    if code.contains(".decode(") {
                        out.push(Finding {
                            file: file.to_string(),
                            line: ln,
                            rule: Rule::LockDiscipline,
                            msg: format!(
                                ".decode( inside the file-lock scope of guard `{g}` — decode \
                                 after dropping the file lock"
                            ),
                        });
                    }
                }
                if !scopes.is_empty() && code.contains(".lock(") {
                    out.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::LockDiscipline,
                        msg: "raw .lock( while a lock_recovering guard is live — nested lock \
                              acquisition is forbidden"
                            .to_string(),
                    });
                }
            }
        }
        // brace bookkeeping runs even through test code so depths stay true
        for ch in code.chars() {
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                scopes.retain(|s| s.depth <= depth);
            }
        }
        scopes.retain(|s| match &s.guard {
            Some(g) => !code.contains(&format!("drop({g})")),
            None => true,
        });
    }
    out
}

struct Allow {
    ann_line: usize,
    target_line: usize,
    rule: Rule,
    used: bool,
}

/// Parse `samplex-lint: allow(rule) -- reason` annotations. An
/// annotation on a code line targets that line; a standalone comment
/// line targets the next line. Malformed annotations become `bad-allow`
/// findings.
fn collect_allows(file: &str, lines: &[Line], mask: &[bool]) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut meta = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        let c = &line.comment;
        let p = match c.find("samplex-lint:") {
            Some(p) => p,
            None => continue,
        };
        let ln = idx + 1;
        let rest = c[p + "samplex-lint:".len()..].trim_start();
        let body = match rest.strip_prefix("allow(") {
            Some(b) => b,
            None => {
                meta.push(Finding {
                    file: file.to_string(),
                    line: ln,
                    rule: Rule::BadAllow,
                    msg: "expected `samplex-lint: allow(<rule>) -- <reason>`".to_string(),
                });
                continue;
            }
        };
        let close = match body.find(')') {
            Some(c) => c,
            None => {
                meta.push(Finding {
                    file: file.to_string(),
                    line: ln,
                    rule: Rule::BadAllow,
                    msg: "unclosed `allow(` in samplex-lint annotation".to_string(),
                });
                continue;
            }
        };
        let name = body[..close].trim();
        let tail = body[close + 1..].trim_start();
        let reason_ok = tail
            .strip_prefix("--")
            .map(|r| !r.trim().is_empty())
            .unwrap_or(false);
        if !reason_ok {
            meta.push(Finding {
                file: file.to_string(),
                line: ln,
                rule: Rule::BadAllow,
                msg: format!("allow({name}) is missing a `-- <reason>` justification"),
            });
            continue;
        }
        let rule = match Rule::from_name(name) {
            Some(r) => r,
            None => {
                meta.push(Finding {
                    file: file.to_string(),
                    line: ln,
                    rule: Rule::BadAllow,
                    msg: format!("unknown rule `{name}` in allow annotation"),
                });
                continue;
            }
        };
        let target_line = if line.code.trim().is_empty() { ln + 1 } else { ln };
        allows.push(Allow { ann_line: ln, target_line, rule, used: false });
    }
    (allows, meta)
}

fn apply_allows(file: &str, raw: &mut Vec<Finding>, allows: &mut [Allow]) -> Vec<Finding> {
    for a in allows.iter_mut() {
        if let Some(pos) = raw
            .iter()
            .position(|f| f.line == a.target_line && f.rule == a.rule)
        {
            raw.remove(pos); // exactly one finding per annotation
            a.used = true;
        }
    }
    allows
        .iter()
        .filter(|a| !a.used)
        .map(|a| Finding {
            file: file.to_string(),
            line: a.ann_line,
            rule: Rule::UnusedAllow,
            msg: format!(
                "allow({}) matched no finding on line {}",
                a.rule.name(),
                a.target_line
            ),
        })
        .collect()
}

/// First function name declared at or after `code`'s `fn ` keyword, if
/// any (used to attach a `#[target_feature]` attribute to its item).
fn fn_name(code: &str) -> Option<String> {
    let bytes = code.as_bytes();
    let mut at = 0usize;
    while let Some(p) = code[at..].find("fn ") {
        let s = at + p;
        let pre_ok = s == 0 || !(bytes[s - 1] == b'_' || bytes[s - 1].is_ascii_alphanumeric());
        if pre_ok {
            let name: String = code[s + 3..]
                .trim_start()
                .chars()
                .take_while(|c| is_ident_char(*c))
                .collect();
            if !name.is_empty() {
                return Some(name);
            }
        }
        at = s + 3;
    }
    None
}

/// R6 pass 1: names of `#[target_feature]` functions in one file. The
/// attribute may sit a few lines above the `fn` header (doc/`SAFETY:`
/// comments and further attributes in between).
fn target_feature_fns(lines: &[Line], mask: &[bool]) -> Vec<String> {
    let mut names = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        if mask[idx] || !line.code.contains("#[target_feature") {
            continue;
        }
        for l in lines.iter().skip(idx).take(8) {
            if let Some(n) = fn_name(&l.code) {
                names.push(n);
                break;
            }
        }
    }
    names
}

/// R6 pass 2 helper: a call-position occurrence of `name` — word-bounded,
/// directly followed by `(`, and not the `fn name(` definition itself.
fn has_direct_call(code: &str, name: &str) -> bool {
    let pat = format!("{name}(");
    let bytes = code.as_bytes();
    let mut at = 0usize;
    while let Some(p) = code[at..].find(&pat) {
        let s = at + p;
        let pre_ok = s == 0 || !(bytes[s - 1] == b'_' || bytes[s - 1].is_ascii_alphanumeric());
        let is_def = code[..s].trim_end().ends_with("fn");
        if pre_ok && !is_def {
            return true;
        }
        at = s + pat.len();
    }
    false
}

const DETERMINISM_TOKENS: [&str; 6] = [
    "HashMap",
    "HashSet",
    "Instant::now",
    "SystemTime::now",
    "thread::current",
    "available_parallelism",
];

/// Lint one file's source. `file` is the display path used both for
/// diagnostics and for rule classification. R6's cross-file call check
/// only sees `#[target_feature]` functions defined in this one file; use
/// [`lint_files`] to check a whole tree.
pub fn lint_source(file: &str, src: &str) -> Vec<Finding> {
    lint_files(&[(file.to_string(), src.to_string())])
}

/// Lint a set of `(display path, source)` files as one unit. This is the
/// full-fidelity entry point: R6 collects `#[target_feature]` function
/// names across *all* files first, then flags out-of-module definitions
/// and direct calls anywhere outside `math/simd/`.
pub fn lint_files(files: &[(String, String)]) -> Vec<Finding> {
    let prepped: Vec<(&str, Vec<Line>, Vec<bool>)> = files
        .iter()
        .map(|(f, src)| {
            let lines = strip_source(src);
            let mask = test_mask(&lines);
            (f.as_str(), lines, mask)
        })
        .collect();
    let mut tf_names: Vec<String> = prepped
        .iter()
        .flat_map(|(_, lines, mask)| target_feature_fns(lines, mask))
        .collect();
    tf_names.sort();
    tf_names.dedup();
    let mut out = Vec::new();
    for (file, lines, mask) in &prepped {
        out.extend(lint_one(file, lines, mask, &tf_names));
    }
    out
}

fn lint_one(file: &str, lines: &[Line], mask: &[bool], tf_names: &[String]) -> Vec<Finding> {
    let class = classify(file);
    let mut raw: Vec<Finding> = Vec::new();

    for (idx, line) in lines.iter().enumerate() {
        if mask[idx] {
            continue;
        }
        let code = &line.code;
        let ln = idx + 1;
        if class.data_plane {
            for tok in ["panic!", "unreachable!", ".unwrap()", ".expect("] {
                for _ in 0..occurrences(code, tok) {
                    raw.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::NoPanicPlane,
                        msg: format!(
                            "{tok} in a data-plane module — thread a typed `Error` instead"
                        ),
                    });
                }
            }
        }
        if class.determinism {
            for tok in DETERMINISM_TOKENS {
                if code.contains(tok) {
                    raw.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::Determinism,
                        msg: format!(
                            "{tok} can feed nondeterministic values into a reduction/fold path"
                        ),
                    });
                }
            }
        }
        let relaxed = occurrences(code, "Ordering::Relaxed");
        if relaxed > 0 && !relaxed_annotated(&lines, idx) {
            for _ in 0..relaxed {
                raw.push(Finding {
                    file: file.to_string(),
                    line: ln,
                    rule: Rule::AtomicsAudit,
                    msg: "Ordering::Relaxed without a `relaxed-ok:` stats-counter annotation — \
                          cross-thread signal flags need Acquire/Release"
                        .to_string(),
                });
            }
        }
        if has_word(code, "unsafe") && !safety_annotated(&lines, idx) {
            raw.push(Finding {
                file: file.to_string(),
                line: ln,
                rule: Rule::SafetyComments,
                msg: "`unsafe` without a `// SAFETY:` comment stating the aliasing/lifetime \
                      argument"
                    .to_string(),
            });
        }
        if class.storage_io {
            for tok in [".read_exact(", ".seek("] {
                for _ in 0..occurrences(code, tok) {
                    raw.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::IoDiscipline,
                        msg: format!(
                            "{tok} in storage/ outside the retry module — route the read \
                             through retry::read_exact_at so it gets bounded retries, the \
                             watchdog deadline and checksum verification"
                        ),
                    });
                }
            }
        }
        if !class.clock_exempt {
            for tok in ["Instant::now", "SystemTime::now"] {
                for _ in 0..occurrences(code, tok) {
                    raw.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::ClockDiscipline,
                        msg: format!(
                            "{tok} outside metrics/ and obs/ — read time through the \
                             metrics::timer::monotonic_ns seam (Stopwatch) so the crate \
                             has exactly one clock"
                        ),
                    });
                }
            }
        }
        if !class.simd_home {
            if code.contains("#[target_feature") {
                raw.push(Finding {
                    file: file.to_string(),
                    line: ln,
                    rule: Rule::SimdDispatch,
                    msg: "#[target_feature] function defined outside math/simd/ — arch \
                          kernels live in the dispatch module only"
                        .to_string(),
                });
            }
            for name in tf_names {
                if has_direct_call(code, name) {
                    raw.push(Finding {
                        file: file.to_string(),
                        line: ln,
                        rule: Rule::SimdDispatch,
                        msg: format!(
                            "direct call to #[target_feature] kernel `{name}` — go through \
                             the dispatched math::simd::KernelSet table"
                        ),
                    });
                }
            }
        }
    }

    if class.pagestore {
        raw.extend(lock_discipline(file, &lines, &mask));
    }

    let (mut allows, mut meta) = collect_allows(file, &lines, &mask);
    let unused = apply_allows(file, &mut raw, &mut allows);
    raw.append(&mut meta);
    raw.extend(unused);
    raw.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    raw
}

/// Recursively collect `.rs` files under `root` in sorted order.
pub fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if root.is_file() {
        if root.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for e in entries {
        if e.is_dir() {
            collect_rs_files(&e, out)?;
        } else if e.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(e);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under the given paths (files or directories) as
/// one unit, so R6's cross-file call check sees the whole tree.
pub fn lint_paths(paths: &[PathBuf]) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs_files(p, &mut files)?;
    }
    let mut sources = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let display = f.to_string_lossy().replace('\\', "/");
        sources.push((display, src));
    }
    Ok(lint_files(&sources))
}

/// Extract the `members = [...]` array from a workspace `Cargo.toml`.
///
/// Hand-rolled on purpose: the lint tool stays zero-dependency, and a
/// workspace manifest's member list is a flat string array — full TOML
/// is not needed. Handles multi-line arrays, `#` comments, and both
/// quote styles cargo accepts for paths. Returns an empty vector when
/// the manifest has no member array (the caller decides whether that is
/// an error).
pub fn parse_workspace_members(manifest: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_array = false;
    for raw in manifest.lines() {
        // strip line comments before looking at anything
        let line = raw.split('#').next().unwrap_or("");
        let mut rest: &str = line;
        if !in_array {
            let Some(pos) = line.find("members") else { continue };
            let after = &line[pos + "members".len()..];
            let Some(eq) = after.find('=') else { continue };
            let Some(br) = after[eq..].find('[') else { continue };
            rest = &after[eq + br + 1..];
            in_array = true;
        }
        // collect quoted entries up to the closing bracket
        let (body, closed) = match rest.find(']') {
            Some(end) => (&rest[..end], true),
            None => (rest, false),
        };
        let mut chars = body.char_indices();
        while let Some((start, c)) = chars.next() {
            if c != '"' && c != '\'' {
                continue;
            }
            let tail = &body[start + 1..];
            if let Some(len) = tail.find(c) {
                out.push(tail[..len].to_string());
                let close = start + 1 + len; // byte index of the closing quote
                while let Some((i, _)) = chars.next() {
                    if i >= close {
                        break;
                    }
                }
            }
        }
        if closed {
            break;
        }
    }
    out
}

/// Resolve the lintable source roots of the cargo workspace rooted at
/// `root`: each member's `src/` directory, in manifest order.
///
/// Members without a `src/` directory are skipped silently (a member may
/// be a pure manifest shim); a manifest with no member array at all is
/// an error, because "lint the workspace" silently linting nothing is
/// exactly the failure mode this function exists to prevent.
pub fn workspace_member_src_dirs(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let manifest = root.join("Cargo.toml");
    let text = std::fs::read_to_string(&manifest)?;
    let members = parse_workspace_members(&text);
    if members.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("no `members = [...]` array in {}", manifest.display()),
        ));
    }
    let mut dirs = Vec::new();
    for m in &members {
        let src = root.join(m).join("src");
        if src.is_dir() {
            dirs.push(src);
        }
    }
    Ok(dirs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(findings: &[Finding]) -> Vec<(usize, &'static str)> {
        findings.iter().map(|f| (f.line, f.rule.name())).collect()
    }

    #[test]
    fn strips_strings_comments_and_chars() {
        let l = strip_source("let x = \"panic!\"; // panic! here\n");
        assert_eq!(l[0].code, "let x = \"\"; ");
        assert!(l[0].comment.contains("panic! here"));
        assert!(!l[0].code.contains("panic!"));
    }

    #[test]
    fn char_literals_blanked_lifetimes_kept() {
        let l = strip_source("let c = 'a'; let s: &'static str = \"x\"; let e = '\\n';\n");
        assert!(l[0].code.contains("&'static str"));
        assert!(!l[0].code.contains("'a'"));
        let l2 = strip_source("let q = 'u'; x.unwrap();\n");
        assert!(l2[0].code.contains(".unwrap()"));
    }

    #[test]
    fn raw_strings_and_nested_block_comments() {
        let l = strip_source("let r = r#\"has .unwrap() inside\"#;\n");
        assert!(!l[0].code.contains("unwrap"));
        let l2 = strip_source("/* outer /* inner .unwrap() */ tail */ code()\n");
        assert!(!l2[0].code.contains("unwrap"));
        assert!(l2[0].code.contains("code()"));
        assert!(l2[0].comment.contains("inner"));
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() { x.unwrap(); }\n}\nfn c() {}\n";
        let lines = strip_source(src);
        let m = test_mask(&lines);
        assert!(!m[0]);
        assert!(m[1] && m[2] && m[3] && m[4]);
        assert!(!m[5]);
    }

    #[test]
    fn classify_paths() {
        assert!(classify("rust/src/data/paged.rs").data_plane);
        assert!(classify("rust/src/storage/pagestore.rs").pagestore);
        assert!(classify("rust/src/math/chunked.rs").data_plane);
        assert!(classify("rust/src/math/chunked.rs").determinism);
        assert!(!classify("rust/src/runtime/pool.rs").data_plane);
        assert!(!classify("rust/src/data.rs").data_plane);
        assert!(classify("rust/src/math/simd/avx2.rs").simd_home);
        assert!(classify("rust/src/math/simd/mod.rs").simd_home);
        assert!(!classify("rust/src/math/dense.rs").simd_home);
        assert!(classify("rust/src/storage/pagestore.rs").storage_io);
        assert!(classify("rust/src/storage/reader.rs").storage_io);
        assert!(!classify("rust/src/storage/retry.rs").storage_io);
        assert!(!classify("rust/src/testing/faults.rs").storage_io);
        assert!(!classify("rust/src/data/paged.rs").storage_io);
        assert!(classify("rust/src/metrics/timer.rs").clock_exempt);
        assert!(classify("rust/src/metrics/ascii_plot.rs").clock_exempt);
        assert!(classify("rust/src/obs/ring.rs").clock_exempt);
        assert!(!classify("rust/src/storage/pagestore.rs").clock_exempt);
        assert!(!classify("rust/src/solvers/sag.rs").clock_exempt);
        assert!(!classify("rust/src/obs.rs").clock_exempt, "file named obs.rs is not the dir");
    }

    #[test]
    fn classify_survives_the_workspace_split() {
        // rule families are keyed on path suffixes and directory segment
        // names, never on a `rust/src` prefix — the same module must
        // classify identically at its post-split `crates/<member>/src`
        // home. One assertion per rule family, old home next to new.
        for prefix in ["rust/src", "crates/samplex-data/src"] {
            assert!(classify(&format!("{prefix}/data/paged.rs")).data_plane, "{prefix}");
            assert!(classify(&format!("{prefix}/storage/pagestore.rs")).pagestore, "{prefix}");
            assert!(classify(&format!("{prefix}/storage/reader.rs")).storage_io, "{prefix}");
            assert!(!classify(&format!("{prefix}/storage/retry.rs")).storage_io, "{prefix}");
            assert!(classify(&format!("{prefix}/math/simd/avx2.rs")).simd_home, "{prefix}");
            assert!(classify(&format!("{prefix}/pipeline/prefetch.rs")).data_plane, "{prefix}");
        }
        for prefix in ["rust/src", "crates/samplex-compute/src"] {
            let c = classify(&format!("{prefix}/math/chunked.rs"));
            assert!(c.data_plane && c.determinism, "{prefix}");
            assert!(classify(&format!("{prefix}/train/parallel.rs")).determinism, "{prefix}");
            assert!(classify(&format!("{prefix}/backend/native.rs")).determinism, "{prefix}");
            assert!(!classify(&format!("{prefix}/runtime/pool.rs")).data_plane, "{prefix}");
        }
        for prefix in ["rust/src", "crates/samplex-obs/src"] {
            assert!(classify(&format!("{prefix}/metrics/timer.rs")).clock_exempt, "{prefix}");
            assert!(classify(&format!("{prefix}/obs/trace.rs")).clock_exempt, "{prefix}");
        }
        // the service and facade crates are in no special family
        let svc = classify("crates/samplex-service/src/serve/mod.rs");
        assert!(!svc.data_plane && !svc.clock_exempt && !svc.storage_io);
        assert!(!classify("rust/src/lib.rs").data_plane);
    }

    #[test]
    fn moved_path_fixture_still_triggers_every_path_scoped_rule() {
        // End-to-end regression for the workspace split: feed fixture
        // sources under their *new* crates/ paths through the real lint
        // pipeline and require the path-scoped rules (R1, R2, R3, R6,
        // R7, R8) to fire exactly as they did under rust/src.
        let pagestore_src = "fn read_page(f: &mut std::fs::File) {\n\
                             \x20   let g = lock_recovering(&self.shards[0]);\n\
                             \x20   f.read_exact(&mut buf).unwrap();\n\
                             }\n";
        let chunked_src = "fn fold() {\n\
                           \x20   let m = std::collections::HashMap::new();\n\
                           }\n";
        let rogue_kernel_src = "#[target_feature(enable = \"avx2\")]\n\
                                // SAFETY: fixture\n\
                                unsafe fn dot_rogue(x: &[f32]) -> f32 { x[0] }\n";
        let clock_src = "fn tick() {\n\
                         \x20   let t = std::time::Instant::now();\n\
                         }\n";
        let findings = lint_files(&[
            (
                "crates/samplex-data/src/storage/pagestore.rs".to_string(),
                pagestore_src.to_string(),
            ),
            (
                "crates/samplex-compute/src/math/chunked.rs".to_string(),
                chunked_src.to_string(),
            ),
            (
                "crates/samplex-compute/src/solvers/sgd.rs".to_string(),
                rogue_kernel_src.to_string(),
            ),
            (
                "crates/samplex-service/src/serve/mod.rs".to_string(),
                clock_src.to_string(),
            ),
        ]);
        let hit = |file: &str, rule: &str| {
            findings
                .iter()
                .any(|f| f.file == file && f.rule.name() == rule)
        };
        let ps = "crates/samplex-data/src/storage/pagestore.rs";
        assert!(hit(ps, "no-panic-plane"), "R1 must survive the move: {findings:?}");
        assert!(hit(ps, "lock-discipline"), "R2 must survive the move: {findings:?}");
        assert!(hit(ps, "io-discipline"), "R7 must survive the move: {findings:?}");
        assert!(
            hit("crates/samplex-compute/src/math/chunked.rs", "determinism"),
            "R3 must survive the move: {findings:?}"
        );
        assert!(
            hit("crates/samplex-compute/src/solvers/sgd.rs", "simd-dispatch"),
            "R6 must survive the move: {findings:?}"
        );
        assert!(
            hit("crates/samplex-service/src/serve/mod.rs", "clock-discipline"),
            "R8 must survive the move: {findings:?}"
        );
    }

    #[test]
    fn parse_workspace_members_handles_real_manifest_shapes() {
        // multi-line array with comments and a trailing comma
        let toml = "[workspace]\n\
                    resolver = \"2\"\n\
                    members = [\n\
                    \x20   \"crates/samplex-obs\",  # tracing plane\n\
                    \x20   \"crates/samplex-data\",\n\
                    \x20   'rust',\n\
                    \x20   \"tools/samplex-lint\",\n\
                    ]\n";
        assert_eq!(
            parse_workspace_members(toml),
            vec!["crates/samplex-obs", "crates/samplex-data", "rust", "tools/samplex-lint"]
        );
        // single-line array
        assert_eq!(
            parse_workspace_members("members = [\"a\", \"b/c\"]\n"),
            vec!["a", "b/c"]
        );
        // no members array at all
        assert!(parse_workspace_members("[package]\nname = \"x\"\n").is_empty());
        // entries after the closing bracket are not collected
        assert_eq!(
            parse_workspace_members("members = [\"a\"]\nexclude = [\"zzz\"]\n"),
            vec!["a"]
        );
    }

    #[test]
    fn workspace_discovery_walks_all_members() {
        // fixture workspace on disk: two members, one with a violation in
        // a data-plane module, one clean — lint_paths over the discovered
        // src dirs must see both and flag exactly the violation
        let root = std::env::temp_dir().join(format!("sxlint_ws_{}", std::process::id()));
        let member_src = root.join("crates/fix-data/src/storage");
        let facade_src = root.join("rust/src");
        std::fs::create_dir_all(&member_src).unwrap();
        std::fs::create_dir_all(&facade_src).unwrap();
        std::fs::write(
            root.join("Cargo.toml"),
            "[workspace]\nmembers = [\n  \"crates/fix-data\",\n  \"rust\",\n  \"gone/member\",\n]\n",
        )
        .unwrap();
        std::fs::write(
            member_src.join("pagestore.rs"),
            "fn f() { let v: Option<u32> = None; v.unwrap(); }\n",
        )
        .unwrap();
        std::fs::write(facade_src.join("lib.rs"), "pub fn ok() {}\n").unwrap();

        let dirs = workspace_member_src_dirs(&root).unwrap();
        // the member without a src dir is skipped, the others found in order
        assert_eq!(dirs.len(), 2);
        assert!(dirs[0].ends_with("crates/fix-data/src"));
        assert!(dirs[1].ends_with("rust/src"));

        let findings = lint_paths(&dirs).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule.name(), "no-panic-plane");
        assert!(findings[0].file.ends_with("storage/pagestore.rs"));

        // a root without a workspace manifest is a hard error, not a
        // silent empty lint
        let empty = root.join("rust");
        std::fs::write(empty.join("Cargo.toml"), "[package]\nname = \"x\"\n").unwrap();
        assert!(workspace_member_src_dirs(&empty).is_err());

        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn r6_direct_call_outside_simd_home_flagged_cross_file() {
        let def = "#[target_feature(enable = \"avx2\")]\n\
                   // SAFETY: fixture\n\
                   unsafe fn dot_impl(x: &[f32]) -> f32 { x[0] }\n";
        let caller = "fn f(x: &[f32]) -> f32 {\n    \
                      // SAFETY: fixture\n    \
                      unsafe { dot_impl(x) }\n}\n";
        let files = vec![
            ("src/math/simd/avx2.rs".to_string(), def.to_string()),
            ("src/solvers/hot.rs".to_string(), caller.to_string()),
        ];
        let got: Vec<(String, usize, &'static str)> = lint_files(&files)
            .into_iter()
            .map(|f| (f.file, f.line, f.rule.name()))
            .collect();
        assert_eq!(got, vec![("src/solvers/hot.rs".to_string(), 3, "simd-dispatch")]);
    }

    #[test]
    fn r6_definition_outside_simd_home_flagged() {
        let src = "#[target_feature(enable = \"avx2\")]\n\
                   // SAFETY: fixture\n\
                   unsafe fn stray_impl(x: &[f32]) -> f32 { x[0] }\n";
        let f = lint_source("src/backend/fast.rs", src);
        assert_eq!(rules_of(&f), vec![(1, "simd-dispatch")]);
    }

    #[test]
    fn r6_allow_suppresses_one_finding() {
        let src = "// samplex-lint: allow(simd-dispatch) -- fixture justification\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   // SAFETY: fixture\n\
                   unsafe fn stray_impl(x: &[f32]) -> f32 { x[0] }\n";
        let f = lint_source("src/backend/fast.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn r1_fires_and_allow_suppresses_one() {
        let src = "fn f(v: Option<u32>) -> u32 {\n    \
                   // samplex-lint: allow(no-panic-plane) -- reason\n    \
                   v.unwrap() + v.unwrap()\n}\n";
        let f = lint_source("src/data/x.rs", src);
        assert_eq!(rules_of(&f), vec![(3, "no-panic-plane")]);
    }

    #[test]
    fn unused_allow_reported_at_annotation_line() {
        let src = "fn f() {}\n// samplex-lint: allow(determinism) -- nothing here\nfn g() {}\n";
        let f = lint_source("src/train/parallel.rs", src);
        assert_eq!(rules_of(&f), vec![(2, "unused-allow")]);
    }

    #[test]
    fn malformed_allow_is_bad_allow() {
        let src = "fn f(v: Option<u32>) -> u32 {\n    \
                   v.unwrap() // samplex-lint: allow(no-panic-plane)\n}\n";
        let f = lint_source("src/data/x.rs", src);
        assert_eq!(rules_of(&f), vec![(2, "no-panic-plane"), (2, "bad-allow")]);
    }

    #[test]
    fn relaxed_marker_covers_contiguous_run_only() {
        let src = "fn f() {\n    \
                   a.load(Ordering::Relaxed); // relaxed-ok: counter\n    \
                   b.load(Ordering::Relaxed);\n    \
                   let x = 1;\n    \
                   c.load(Ordering::Relaxed);\n}\n";
        let f = lint_source("src/misc.rs", src);
        assert_eq!(rules_of(&f), vec![(5, "atomics-audit")]);
    }

    #[test]
    fn safety_comment_same_line_or_block_above() {
        let src = "// SAFETY: p is valid\nunsafe { read(p) }\nunsafe { read(q) }\n";
        let f = lint_source("src/misc.rs", src);
        assert_eq!(rules_of(&f), vec![(3, "safety-comments")]);
    }

    #[test]
    fn lock_scope_tracks_bindings_and_drop() {
        // the `.seek(` lines additionally violate R7 now that raw reads
        // in storage/ must route through the retry module
        let src = "fn bad(&self) {\n    \
                   let mut shard = lock_recovering(self.shard(id));\n    \
                   self.file.seek(SeekFrom::Start(0));\n    \
                   drop(shard);\n    \
                   self.file.seek(SeekFrom::Start(0));\n}\n";
        let f = lint_source("src/storage/pagestore.rs", src);
        assert_eq!(
            rules_of(&f),
            vec![(3, "lock-discipline"), (3, "io-discipline"), (5, "io-discipline")]
        );
    }

    #[test]
    fn r7_raw_reads_flagged_everywhere_in_storage_but_retry() {
        let src = "fn pull(&mut self) -> io::Result<()> {\n    \
                   self.file.seek(SeekFrom::Start(8))?;\n    \
                   self.file.read_exact(&mut self.buf)\n}\n";
        let f = lint_source("src/storage/reader.rs", src);
        assert_eq!(rules_of(&f), vec![(2, "io-discipline"), (3, "io-discipline")]);
        assert!(lint_source("src/storage/retry.rs", src).is_empty(), "retry.rs is exempt");
        assert!(lint_source("src/testing/faults.rs", src).is_empty(), "outside storage/");
    }

    #[test]
    fn r8_clock_reads_flagged_outside_metrics_and_obs() {
        let src = "fn f() {\n    \
                   let t = std::time::Instant::now();\n    \
                   let s = SystemTime::now();\n}\n";
        let f = lint_source("src/solvers/stepper.rs", src);
        assert_eq!(rules_of(&f), vec![(2, "clock-discipline"), (3, "clock-discipline")]);
        assert!(lint_source("src/metrics/timer.rs", src).is_empty(), "metrics/ is exempt");
        assert!(lint_source("src/obs/ring.rs", src).is_empty(), "obs/ is exempt");
    }

    #[test]
    fn r8_allow_suppresses_one_finding() {
        let src = "fn f() {\n    \
                   // samplex-lint: allow(clock-discipline) -- fixture justification\n    \
                   let t = std::time::Instant::now();\n}\n";
        assert!(lint_source("src/runtime/pool.rs", src).is_empty());
    }

    #[test]
    fn nested_lock_acquisition_flagged() {
        let src = "fn bad(&self) {\n    \
                   let f = lock_recovering(&self.file);\n    \
                   let s = lock_recovering(self.shard(0));\n}\n";
        let f = lint_source("src/storage/pagestore.rs", src);
        assert_eq!(rules_of(&f), vec![(3, "lock-discipline")]);
    }
}
