//! Fixture-backed tests: one violating + one conforming fixture per
//! rule (R1-R8), exact `line rule` diagnostics, allow suppression, and
//! the binary's exit-code contract.

use std::path::{Path, PathBuf};

use samplex_lint::lint_source;

fn fixture_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

fn lint_fixture(rel: &str) -> Vec<(usize, &'static str)> {
    let src = std::fs::read_to_string(fixture_path(rel)).unwrap();
    // lint under the repo-relative style path so classification sees the
    // same segments CI does
    let display = format!("tests/fixtures/{rel}");
    lint_source(&display, &src)
        .into_iter()
        .map(|f| (f.line, f.rule.name()))
        .collect()
}

#[test]
fn r1_violating_exact_diagnostics() {
    assert_eq!(
        lint_fixture("r1/data/violating.rs"),
        vec![
            (2, "no-panic-plane"),
            (4, "no-panic-plane"),
            (7, "no-panic-plane"),
            (8, "no-panic-plane"),
        ]
    );
}

#[test]
fn r1_conforming_is_clean() {
    assert!(lint_fixture("r1/data/conforming.rs").is_empty());
}

#[test]
fn r1_allow_suppresses_precisely_one_finding() {
    // two annotated sites: one suppresses the first of two single-finding
    // lines, one suppresses one of two findings on the same line
    assert_eq!(
        lint_fixture("r1/data/allowed.rs"),
        vec![(6, "no-panic-plane"), (12, "no-panic-plane")]
    );
}

#[test]
fn r2_violating_exact_diagnostics() {
    // the raw `.seek(`/`.read_exact(` lines violate both the lock scope
    // (R2) and the storage read discipline (R7)
    assert_eq!(
        lint_fixture("r2/storage/pagestore.rs"),
        vec![
            (3, "lock-discipline"),
            (4, "lock-discipline"),
            (4, "io-discipline"),
            (5, "lock-discipline"),
            (5, "io-discipline"),
            (6, "lock-discipline"),
            (6, "lock-discipline"),
        ]
    );
}

#[test]
fn r2_conforming_is_clean() {
    assert!(lint_fixture("r2_ok/storage/pagestore.rs").is_empty());
}

#[test]
fn r3_violating_exact_diagnostics() {
    // line 2's Instant::now additionally violates the crate-wide clock
    // discipline (R8) now that raw clock reads live only in metrics/obs
    assert_eq!(
        lint_fixture("r3/train/parallel.rs"),
        vec![(2, "determinism"), (2, "clock-discipline"), (3, "determinism")]
    );
}

#[test]
fn r3_conforming_is_clean() {
    assert!(lint_fixture("r3_ok/train/parallel.rs").is_empty());
}

#[test]
fn r4_violating_exact_diagnostics() {
    assert_eq!(
        lint_fixture("r4/counters.rs"),
        vec![(2, "atomics-audit"), (3, "atomics-audit")]
    );
}

#[test]
fn r4_conforming_is_clean() {
    // one block marker covers the contiguous snapshot run; a same-line
    // marker covers the counter bump
    assert!(lint_fixture("r4_ok/counters.rs").is_empty());
}

#[test]
fn r5_violating_exact_diagnostics() {
    assert_eq!(
        lint_fixture("r5/ptr.rs"),
        vec![(2, "safety-comments"), (5, "safety-comments")]
    );
}

#[test]
fn r5_conforming_is_clean() {
    assert!(lint_fixture("r5_ok/ptr.rs").is_empty());
}

#[test]
fn r6_violating_exact_diagnostics_cross_file() {
    // the whole r6 tree is linted as one unit: `dot_avx2_impl` is defined
    // (legitimately) in math/simd/kernels.rs, and backend.rs both defines
    // a stray #[target_feature] kernel and calls two kernels directly
    let findings = samplex_lint::lint_paths(&[fixture_path("r6")]).unwrap();
    let got: Vec<(usize, &'static str)> =
        findings.iter().map(|f| (f.line, f.rule.name())).collect();
    assert!(
        findings.iter().all(|f| f.file.ends_with("backend.rs")),
        "math/simd/ definitions must stay clean: {findings:?}"
    );
    assert_eq!(
        got,
        vec![(1, "simd-dispatch"), (9, "simd-dispatch"), (9, "simd-dispatch")]
    );
}

#[test]
fn r6_conforming_is_clean() {
    // same kernels, but the caller goes through the KernelSet table
    let findings = samplex_lint::lint_paths(&[fixture_path("r6_ok")]).unwrap();
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn r7_violating_exact_diagnostics() {
    assert_eq!(
        lint_fixture("r7/storage/reader.rs"),
        vec![(2, "io-discipline"), (3, "io-discipline")]
    );
}

#[test]
fn r7_conforming_tree_is_clean() {
    // the retry module's own raw reads are exempt, reads routed through
    // retry::read_exact_at are clean, and testing/ is out of scope
    let findings = samplex_lint::lint_paths(&[fixture_path("r7_ok")]).unwrap();
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn r8_violating_exact_diagnostics() {
    assert_eq!(
        lint_fixture("r8/solvers/stepper.rs"),
        vec![(3, "clock-discipline"), (8, "clock-discipline")]
    );
}

#[test]
fn r8_conforming_tree_is_clean() {
    // metrics/ owns the raw clock read behind the monotonic seam; obs/
    // consumes the seam — both are sanctioned homes
    let findings = samplex_lint::lint_paths(&[fixture_path("r8_ok")]).unwrap();
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn malformed_and_unknown_allows_are_bad_allow() {
    assert_eq!(
        lint_fixture("meta/data/bad_allow.rs"),
        vec![
            (2, "bad-allow"),
            (3, "no-panic-plane"),
            (4, "bad-allow"),
            (5, "no-panic-plane"),
        ]
    );
}

#[test]
fn allow_that_suppresses_nothing_is_unused_allow() {
    assert_eq!(lint_fixture("meta/data/unused_allow.rs"), vec![(2, "unused-allow")]);
}

#[test]
fn binary_exits_nonzero_with_diagnostics_on_violations() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_samplex-lint"))
        .arg(fixture_path("r1/data/violating.rs"))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(
        stdout.contains("violating.rs:2 no-panic-plane"),
        "machine-readable file:line rule output expected, got:\n{stdout}"
    );
}

#[test]
fn binary_exits_zero_on_clean_tree() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_samplex-lint"))
        .arg(fixture_path("r1/data/conforming.rs"))
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(0));
    assert!(out.stdout.is_empty());
}

#[test]
fn binary_exits_2_on_bad_usage() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_samplex-lint"))
        .arg("no/such/path.rs")
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2));
}
