pub fn pull(&mut self) -> io::Result<()> {
    self.file.seek(SeekFrom::Start(8))?;
    self.file.read_exact(&mut self.buf)
}
