pub fn read_page(&self, id: u32) -> Page {
    {
        let mut f = lock_recovering(&self.file);
        retry::read_exact_at(&mut f, self.offset(id), &mut self.buf, &self.retry, id as u64, "page read");
    }
    let page = self.buf.decode(id);
    let mut shard = lock_recovering(self.shard(id));
    shard.insert(id, page)
}
