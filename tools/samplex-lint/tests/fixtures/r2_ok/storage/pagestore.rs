pub fn read_page(&self, id: u32) -> Page {
    {
        let mut f = lock_recovering(&self.file);
        f.seek(SeekFrom::Start(self.offset(id)));
        f.read_exact(&mut self.buf);
    }
    let page = self.buf.decode(id);
    let mut shard = lock_recovering(self.shard(id));
    shard.insert(id, page)
}
