#[target_feature(enable = "avx2")]
// SAFETY: fixture
unsafe fn stray_impl(x: &[f32]) -> f32 {
    x[0]
}

pub fn hot_loop(x: &[f32], w: &[f32]) -> f32 {
    // SAFETY: fixture
    unsafe { dot_avx2_impl(x, w) + stray_impl(x) }
}
