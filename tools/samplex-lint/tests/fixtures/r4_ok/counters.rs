impl Stats {
    pub fn snapshot(&self) -> (u64, u64) {
        // relaxed-ok: monotonic stats counters read for reporting only;
        // no thread observes them for synchronization.
        let h = self.hits.load(Ordering::Relaxed);
        let m = self.misses.load(Ordering::Relaxed);
        (h, m)
    }

    pub fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed); // relaxed-ok: pure counter
    }
}
