pub fn fold(parts: &[f32]) -> f32 {
    let mut keyed: Vec<(usize, f32)> = Vec::new();
    for (i, p) in parts.iter().enumerate() {
        keyed.push((i, *p));
    }
    let mut total = 0.0;
    for (_, v) in &keyed {
        total += v;
    }
    total
}
