pub fn read_first(p: *const u8) -> u8 {
    // SAFETY: caller contract says `p` points at one readable byte.
    unsafe { *p }
}

pub fn read_second(p: *const u8) -> u8 {
    unsafe { *p.add(1) } // SAFETY: caller contract: two readable bytes.
}

// SAFETY: Wrapper owns its allocation; no thread-affine state inside.
unsafe impl Send for Wrapper {}
