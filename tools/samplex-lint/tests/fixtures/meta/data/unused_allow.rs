pub fn g(v: u32) -> u32 {
    // samplex-lint: allow(no-panic-plane) -- nothing to suppress here
    v + 1
}
