pub fn f(v: Option<u32>) -> u32 {
    // samplex-lint: allow(no-panic-plane)
    let a = v.unwrap();
    // samplex-lint: allow(not-a-rule) -- reason text
    let b = v.unwrap();
    a + b
}
