pub fn step(w: &mut [f32]) {
    // a solver must never read the wall clock directly
    let t0 = std::time::Instant::now();
    for v in w.iter_mut() {
        *v *= 0.99;
    }
    let _ = t0.elapsed();
    let _stamp = std::time::SystemTime::now();
}
