//! One allow annotation suppresses exactly one finding.

pub fn lookup(v: Option<u32>) -> u32 {
    // samplex-lint: allow(no-panic-plane) -- construction guarantees Some here
    let first = v.unwrap();
    let second = v.unwrap();
    first + second
}

pub fn pair(a: Option<u32>, b: Option<u32>) -> u32 {
    // samplex-lint: allow(no-panic-plane) -- left operand is checked by the caller
    a.unwrap() + b.unwrap()
}
