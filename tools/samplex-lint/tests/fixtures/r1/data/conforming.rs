//! Conforming twin: every panic token here is in a string, a comment,
//! a non-matching method name, or test-only code.

pub fn parse(v: Option<u32>) -> u32 {
    // unwrap() in a comment is fine; so is panic! here
    let msg = "calling unwrap() or panic! in a string is data, not code";
    let a = v.unwrap_or_else(|| msg.len() as u32);
    a.min(10)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_only_unwrap_is_fine() {
        super::parse(Some(3));
        Some(1).unwrap();
    }
}
