pub fn parse(v: Option<u32>) -> u32 {
    let a = v.unwrap();
    if a > 10 {
        panic!("too big");
    }
    match a {
        0 => unreachable!("zero handled by caller"),
        _ => v.expect("checked above"),
    }
}
