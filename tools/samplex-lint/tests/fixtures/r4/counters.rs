pub fn bump(c: &AtomicU64, flag: &AtomicBool) {
    c.fetch_add(1, Ordering::Relaxed);
    flag.store(true, Ordering::Relaxed);
}
