pub struct KernelSet {
    pub dot: fn(&[f32], &[f32]) -> f32,
}

#[target_feature(enable = "avx2")]
// SAFETY: reached only after the dispatcher's runtime avx2 check
unsafe fn dot_avx2_impl(x: &[f32], w: &[f32]) -> f32 {
    let mut s = 0.0;
    for k in 0..x.len().min(w.len()) {
        s += x[k] * w[k];
    }
    s
}

fn dot_avx2(x: &[f32], w: &[f32]) -> f32 {
    // SAFETY: table entries are installed only when avx2 was detected
    unsafe { dot_avx2_impl(x, w) }
}

pub static AVX2: KernelSet = KernelSet { dot: dot_avx2 };
