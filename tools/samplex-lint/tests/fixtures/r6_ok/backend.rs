use crate::math::simd::KernelSet;

pub fn hot_loop(ks: &KernelSet, x: &[f32], w: &[f32]) -> f32 {
    (ks.dot)(x, w)
}
