pub fn pull(&mut self, policy: &RetryPolicy) -> Result<()> {
    retry::read_exact_at(&mut self.file, 8, &mut self.buf, policy, 0, "header read")?;
    Ok(())
}
