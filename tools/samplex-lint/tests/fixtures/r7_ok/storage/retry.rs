pub fn read_exact_at(f: &mut File, offset: u64, buf: &mut [u8]) -> io::Result<()> {
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(buf)
}
