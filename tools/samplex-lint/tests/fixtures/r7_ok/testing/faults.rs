pub fn read_some(&mut self, buf: &mut [u8]) -> io::Result<usize> {
    self.file.seek(SeekFrom::Start(self.at))?;
    self.file.read(buf)
}
