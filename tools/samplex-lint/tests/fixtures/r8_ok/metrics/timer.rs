use std::sync::OnceLock;
use std::time::Instant;

static BASE: OnceLock<Instant> = OnceLock::new();

/// The one sanctioned raw clock read: everything else goes through here.
pub fn monotonic_ns() -> u64 {
    let base = BASE.get_or_init(Instant::now);
    base.elapsed().as_nanos() as u64
}
