/// The tracing plane timestamps spans through the shared seam.
pub fn stamp() -> u64 {
    crate::metrics::timer::monotonic_ns()
}
