/// Non-exempt modules measure time through the Stopwatch over the seam.
pub fn timed_epoch(work: impl FnOnce()) -> f64 {
    let sw = crate::metrics::timer::Stopwatch::start();
    work();
    sw.elapsed_s()
}
