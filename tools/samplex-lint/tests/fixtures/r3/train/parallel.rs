pub fn fold(parts: &[f32]) -> f32 {
    let started = Instant::now();
    let mut seen = HashMap::new();
    for (i, p) in parts.iter().enumerate() {
        seen.insert(i, *p);
    }
    let mut total = 0.0;
    for v in seen.values() {
        total += v;
    }
    let _ = started.elapsed();
    total
}
