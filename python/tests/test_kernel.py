"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes, masks, tiles and value ranges; every property
asserts allclose against ``kernels.ref``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.logreg import (
    DEFAULT_TILE,
    _pick_tile,
    logreg_grad_data,
    logreg_loss_sum,
)

RTOL = 2e-5
ATOL = 1e-5


def _mk(rng, b, n, mask_kind="full"):
    x = rng.normal(size=(b, n)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=b).astype(np.float32)
    if mask_kind == "full":
        mask = np.ones(b, np.float32)
    elif mask_kind == "tail":
        keep = max(1, b - rng.integers(0, b))
        mask = np.zeros(b, np.float32)
        mask[:keep] = 1.0
    else:  # random
        mask = rng.choice([0.0, 1.0], size=b).astype(np.float32)
        if mask.sum() == 0:
            mask[0] = 1.0
    w = rng.normal(size=n).astype(np.float32)
    scale = np.array([1.0 / mask.sum()], np.float32)
    return map(jnp.asarray, (x, y, mask, w, scale))


# ---------------------------------------------------------------------------
# Deterministic spot checks
# ---------------------------------------------------------------------------

class TestGradKernel:
    @pytest.mark.parametrize("b,n", [(200, 28), (500, 18), (1000, 54), (100, 512)])
    def test_matches_ref_registry_shapes(self, b, n):
        x, y, mask, w, scale = _mk(np.random.default_rng(b * n), b, n)
        got = logreg_grad_data(x, y, mask, w, scale)
        want = ref.logreg_grad_data_ref(x, y, mask, w, scale)
        assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    @pytest.mark.parametrize("mask_kind", ["tail", "random"])
    def test_masked_rows_contribute_nothing(self, mask_kind):
        rng = np.random.default_rng(7)
        x, y, mask, w, scale = _mk(rng, 200, 22, mask_kind)
        got = logreg_grad_data(x, y, mask, w, scale)
        # corrupting masked rows must not change the gradient
        x2 = np.asarray(x).copy()
        x2[np.asarray(mask) == 0.0] = 1e6
        got2 = logreg_grad_data(jnp.asarray(x2), y, mask, w, scale)
        assert_allclose(got, got2, rtol=0, atol=0)

    def test_explicit_tile_equals_default(self):
        x, y, mask, w, scale = _mk(np.random.default_rng(3), 200, 28)
        a = logreg_grad_data(x, y, mask, w, scale)
        b = logreg_grad_data(x, y, mask, w, scale, tile=200)
        c = logreg_grad_data(x, y, mask, w, scale, tile=50)
        assert_allclose(a, b, rtol=RTOL, atol=ATOL)
        assert_allclose(a, c, rtol=RTOL, atol=ATOL)

    def test_non_dividing_tile_raises(self):
        x, y, mask, w, scale = _mk(np.random.default_rng(3), 200, 8)
        with pytest.raises(ValueError):
            logreg_grad_data(x, y, mask, w, scale, tile=3)

    def test_zero_w_gives_half_sigmoid_gradient(self):
        # at w=0, sigmoid(-y*0)=0.5, so g = -0.5 * mean(y_i x_i)
        rng = np.random.default_rng(11)
        x, y, mask, w, scale = _mk(rng, 100, 10)
        w = jnp.zeros_like(w)
        got = logreg_grad_data(x, y, mask, w, scale)
        want = -(0.5 * np.asarray(y)[:, None] * np.asarray(x)).mean(axis=0)
        assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestLossKernel:
    @pytest.mark.parametrize("b,n", [(200, 28), (500, 100), (1000, 18)])
    def test_matches_ref(self, b, n):
        x, y, mask, w, _ = _mk(np.random.default_rng(b + n), b, n)
        got = logreg_loss_sum(x, y, mask, w)
        want = ref.logreg_loss_sum_ref(x, y, mask, w)
        assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_numerical_stability_large_margin(self):
        # |y z| huge: naive log(1+exp(.)) overflows; logaddexp must not
        n = 8
        x = jnp.full((100, n), 100.0, jnp.float32)
        w = jnp.full((n,), 100.0, jnp.float32)
        y = jnp.concatenate([jnp.ones(50), -jnp.ones(50)]).astype(jnp.float32)
        mask = jnp.ones(100, jnp.float32)
        got = np.asarray(logreg_loss_sum(x, y, mask, w))
        assert np.isfinite(got).all()
        want = np.asarray(ref.logreg_loss_sum_ref(x, y, mask, w))
        assert_allclose(got, want, rtol=1e-6)

    def test_loss_at_zero_w_is_log2(self):
        x, y, mask, w, _ = _mk(np.random.default_rng(5), 100, 12)
        got = logreg_loss_sum(x, y, mask, jnp.zeros_like(w))
        assert_allclose(got, [100 * np.log(2.0)], rtol=1e-6)


class TestTilePicker:
    @pytest.mark.parametrize("b", [1, 2, 7, 100, 200, 500, 737, 1000, 4096])
    def test_tile_divides(self, b):
        t = _pick_tile(b)
        assert b % t == 0 and 1 <= t <= max(b, 1)

    def test_registry_batches_use_big_tiles(self):
        assert _pick_tile(200) == 200
        assert _pick_tile(500) == 100
        assert _pick_tile(1000) == 200
        assert DEFAULT_TILE == 100

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            _pick_tile(0)


# ---------------------------------------------------------------------------
# Hypothesis property sweeps
# ---------------------------------------------------------------------------

@st.composite
def problem(draw, max_b=64, max_n=48):
    b = draw(st.integers(1, max_b))
    n = draw(st.integers(1, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    mask_kind = draw(st.sampled_from(["full", "tail", "random"]))
    return b, n, seed, mask_kind


@settings(max_examples=40, deadline=None)
@given(problem())
def test_grad_property_sweep(p):
    b, n, seed, mask_kind = p
    x, y, mask, w, scale = _mk(np.random.default_rng(seed), b, n, mask_kind)
    got = logreg_grad_data(x, y, mask, w, scale)
    want = ref.logreg_grad_data_ref(x, y, mask, w, scale)
    assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=40, deadline=None)
@given(problem())
def test_loss_property_sweep(p):
    b, n, seed, mask_kind = p
    x, y, mask, w, _ = _mk(np.random.default_rng(seed), b, n, mask_kind)
    got = logreg_loss_sum(x, y, mask, w)
    want = ref.logreg_loss_sum_ref(x, y, mask, w)
    assert_allclose(got, want, rtol=5e-5, atol=5e-5)


@settings(max_examples=20, deadline=None)
@given(problem(max_b=32, max_n=24), st.floats(1e-4, 10.0))
def test_grad_is_gradient_of_obj(p, c_val):
    """Finite-difference check: batch_grad is d(batch_obj)/dw."""
    from compile import model

    b, n, seed, _ = p
    x, y, mask, w, scale = _mk(np.random.default_rng(seed), b, n, "full")
    c = jnp.array([c_val], jnp.float32)

    def obj64(wv):
        z = np.asarray(x, np.float64) @ wv
        yv = np.asarray(y, np.float64)
        return (np.logaddexp(0, -yv * z).mean()
                + 0.5 * float(c[0]) * wv @ wv)

    g = np.asarray(model.batch_grad(w, x, y, mask, scale, c)[0], np.float64)
    w64 = np.asarray(w, np.float64)
    eps = 1e-6
    for k in range(min(n, 4)):
        e = np.zeros(n)
        e[k] = eps
        fd = (obj64(w64 + e) - obj64(w64 - e)) / (2 * eps)
        assert abs(fd - g[k]) < 5e-3 * max(1.0, abs(fd))
