"""AOT lowering: manifest structure, HLO-text validity, shape bookkeeping."""
import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_grid(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out, dims=[6], batches=[20], quiet=True)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out, manifest


EXPECTED_ENTRYPOINTS = {"grad", "obj", "loss_sum", "mbsgd", "sag", "saga",
                        "svrg", "saag2"}


def test_manifest_covers_all_entrypoints(small_grid):
    _, manifest = small_grid
    names = {e["entrypoint"] for e in manifest["entries"].values()}
    assert names == EXPECTED_ENTRYPOINTS
    assert len(manifest["entries"]) == len(EXPECTED_ENTRYPOINTS)


def test_hlo_text_is_parseable_entry(small_grid):
    out, manifest = small_grid
    for e in manifest["entries"].values():
        text = open(os.path.join(out, e["file"])).read()
        assert "ENTRY" in text and "ROOT" in text, e["file"]
        # interchange must be text, never a serialized proto blob
        assert text.isprintable() or "\n" in text


def test_param_shapes_match_convention(small_grid):
    _, manifest = small_grid
    g = manifest["entries"]["grad_B20_n6"]
    assert g["param_shapes"] == [[6], [20, 6], [20], [20], [1], [1]]
    s = manifest["entries"]["saga_B20_n6"]
    assert s["param_shapes"][-3:] == [[6], [6], [1]]


def test_keys_encode_shape(small_grid):
    _, manifest = small_grid
    for key, e in manifest["entries"].items():
        assert key == f"{e['entrypoint']}_B{e['batch']}_n{e['features']}"


def test_format_fields(small_grid):
    _, manifest = small_grid
    assert manifest["format"] == "hlo-text"
    assert manifest["dtype"] == "f32"
    assert manifest["return_tuple"] is True
