"""Layer-2 solver-step algebra vs independent numpy references.

The fused steps in ``model.py`` are the exact update rules of DESIGN.md §6;
each is re-derived here in plain numpy from the ``ref`` gradient oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

RTOL, ATOL = 2e-5, 2e-5


@pytest.fixture
def prob():
    rng = np.random.default_rng(42)
    b, n = 100, 16
    x = jnp.asarray(rng.normal(size=(b, n)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], b).astype(np.float32))
    mask = jnp.ones(b, jnp.float32)
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    ic = jnp.array([1.0 / b], jnp.float32)
    c = jnp.array([0.1], jnp.float32)
    lr = jnp.array([0.05], jnp.float32)
    return x, y, mask, w, ic, c, lr, rng


def _gref(w, x, y, mask, ic, c):
    return np.asarray(ref.batch_grad_ref(w, x, y, mask, ic, c))


class TestMbsgd:
    def test_update(self, prob):
        x, y, mask, w, ic, c, lr, _ = prob
        (w2,) = model.mbsgd_step(w, x, y, mask, ic, c, lr)
        want = np.asarray(w) - 0.05 * _gref(w, x, y, mask, ic, c)
        assert_allclose(w2, want, rtol=RTOL, atol=ATOL)

    def test_zero_lr_is_identity(self, prob):
        x, y, mask, w, ic, c, _, _ = prob
        (w2,) = model.mbsgd_step(w, x, y, mask, ic, c, jnp.zeros(1))
        assert_allclose(w2, w, rtol=0, atol=0)

    def test_descends_objective(self, prob):
        x, y, mask, w, ic, c, _, _ = prob
        lr = jnp.array([0.01], jnp.float32)
        (o0,) = model.batch_obj(w, x, y, mask, ic, c)
        (w2,) = model.mbsgd_step(w, x, y, mask, ic, c, lr)
        (o1,) = model.batch_obj(w2, x, y, mask, ic, c)
        assert float(o1) < float(o0)


class TestSag:
    def test_update(self, prob):
        x, y, mask, w, ic, c, lr, rng = prob
        n = w.shape[0]
        yj = jnp.asarray(rng.normal(size=n).astype(np.float32))
        avg = jnp.asarray(rng.normal(size=n).astype(np.float32))
        inv_m = jnp.array([1.0 / 8], jnp.float32)
        w2, yj2, avg2 = model.sag_step(w, x, y, mask, ic, c, lr, yj, avg, inv_m)
        g = _gref(w, x, y, mask, ic, c)
        avg_want = np.asarray(avg) + (g - np.asarray(yj)) / 8
        assert_allclose(avg2, avg_want, rtol=RTOL, atol=ATOL)
        assert_allclose(yj2, g, rtol=RTOL, atol=ATOL)
        assert_allclose(w2, np.asarray(w) - 0.05 * avg_want, rtol=RTOL, atol=ATOL)


class TestSaga:
    def test_update(self, prob):
        x, y, mask, w, ic, c, lr, rng = prob
        n = w.shape[0]
        yj = jnp.asarray(rng.normal(size=n).astype(np.float32))
        avg = jnp.asarray(rng.normal(size=n).astype(np.float32))
        inv_m = jnp.array([0.125], jnp.float32)
        w2, yj2, avg2 = model.saga_step(w, x, y, mask, ic, c, lr, yj, avg, inv_m)
        g = _gref(w, x, y, mask, ic, c)
        assert_allclose(w2, np.asarray(w) - 0.05 * (g - np.asarray(yj) + np.asarray(avg)),
                        rtol=RTOL, atol=ATOL)
        assert_allclose(avg2, np.asarray(avg) + 0.125 * (g - np.asarray(yj)),
                        rtol=RTOL, atol=ATOL)
        assert_allclose(yj2, g, rtol=RTOL, atol=ATOL)

    def test_unbiased_at_memory_equals_gradient(self, prob):
        # if y_j == g_j(w) and avg == g_j(w), SAGA step == MBSGD step
        x, y, mask, w, ic, c, lr, _ = prob
        g = jnp.asarray(_gref(w, x, y, mask, ic, c))
        w_saga, _, _ = model.saga_step(w, x, y, mask, ic, c, lr, g, g,
                                       jnp.array([0.1], jnp.float32))
        (w_sgd,) = model.mbsgd_step(w, x, y, mask, ic, c, lr)
        assert_allclose(w_saga, w_sgd, rtol=RTOL, atol=ATOL)


class TestSvrg:
    def test_update(self, prob):
        x, y, mask, w, ic, c, lr, rng = prob
        n = w.shape[0]
        w_snap = jnp.asarray(rng.normal(size=n).astype(np.float32))
        mu = jnp.asarray(rng.normal(size=n).astype(np.float32))
        (w2,) = model.svrg_step(w, w_snap, mu, x, y, mask, ic, c, lr)
        g = _gref(w, x, y, mask, ic, c)
        gs = _gref(w_snap, x, y, mask, ic, c)
        assert_allclose(w2, np.asarray(w) - 0.05 * (g - gs + np.asarray(mu)),
                        rtol=RTOL, atol=ATOL)

    def test_at_snapshot_uses_full_gradient(self, prob):
        # w == w_snap: correction cancels, step follows mu exactly
        x, y, mask, w, ic, c, lr, rng = prob
        mu = jnp.asarray(rng.normal(size=w.shape[0]).astype(np.float32))
        (w2,) = model.svrg_step(w, w, mu, x, y, mask, ic, c, lr)
        assert_allclose(w2, np.asarray(w) - 0.05 * np.asarray(mu),
                        rtol=RTOL, atol=ATOL)


class TestSaag2:
    def test_update_and_accumulator(self, prob):
        x, y, mask, w, ic, c, lr, rng = prob
        n = w.shape[0]
        acc = jnp.asarray(rng.normal(size=n).astype(np.float32))
        m, j = 8, 3
        coeff = jnp.array([(m - j) / m], jnp.float32)
        inv_m = jnp.array([1.0 / m], jnp.float32)
        w2, acc2 = model.saag2_step(w, x, y, mask, ic, c, lr, acc, coeff, inv_m)
        g = _gref(w, x, y, mask, ic, c)
        d = np.asarray(acc) / m + (m - j) / m * g
        assert_allclose(w2, np.asarray(w) - 0.05 * d, rtol=RTOL, atol=ATOL)
        assert_allclose(acc2, np.asarray(acc) + g, rtol=RTOL, atol=ATOL)

    def test_first_batch_of_epoch_is_mbsgd(self, prob):
        # j=0, acc=0: d = g, identical to MBSGD
        x, y, mask, w, ic, c, lr, _ = prob
        n = w.shape[0]
        w2, _ = model.saag2_step(w, x, y, mask, ic, c, lr, jnp.zeros(n),
                                 jnp.ones(1), jnp.array([0.125], jnp.float32))
        (w_sgd,) = model.mbsgd_step(w, x, y, mask, ic, c, lr)
        assert_allclose(w2, w_sgd, rtol=RTOL, atol=ATOL)


class TestPaddingExactness:
    def test_padded_equals_unpadded(self):
        """A batch padded to a larger static shape gives bit-equal results."""
        rng = np.random.default_rng(9)
        b_real, b_pad, n = 60, 100, 12
        x = rng.normal(size=(b_real, n)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], b_real).astype(np.float32)
        w = jnp.asarray(rng.normal(size=n).astype(np.float32))
        c = jnp.array([0.3], jnp.float32)
        ic = jnp.array([1.0 / b_real], jnp.float32)

        xp = np.zeros((b_pad, n), np.float32)
        xp[:b_real] = x
        yp = np.ones(b_pad, np.float32)
        yp[:b_real] = y
        mp = np.zeros(b_pad, np.float32)
        mp[:b_real] = 1.0

        (g_small,) = model.batch_grad(w, jnp.asarray(x), jnp.asarray(y),
                                      jnp.ones(b_real), ic, c)
        (g_pad,) = model.batch_grad(w, jnp.asarray(xp), jnp.asarray(yp),
                                    jnp.asarray(mp), ic, c)
        assert_allclose(g_pad, g_small, rtol=1e-6, atol=1e-7)

        (o_small,) = model.batch_obj(w, jnp.asarray(x), jnp.asarray(y),
                                     jnp.ones(b_real), ic, c)
        (o_pad,) = model.batch_obj(w, jnp.asarray(xp), jnp.asarray(yp),
                                   jnp.asarray(mp), ic, c)
        assert_allclose(o_pad, o_small, rtol=1e-6, atol=1e-7)
