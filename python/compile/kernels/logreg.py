"""Layer-1 Pallas kernels: the compute hot-spot of l2-regularized logistic ERM.

Two kernels, both tiled over the mini-batch (row) dimension so each row tile of
``X`` streams through VMEM exactly once per call — the TPU analogue of the
paper's "access each datum once, contiguously":

* ``logreg_grad_data``  — data term of the mini-batch gradient,
  ``g = X^T ( sigmoid(-y * (X @ w)) * (-y) * mask ) * scale``.
* ``logreg_loss_sum``   — masked logistic loss sum,
  ``L = sum_i mask_i * log(1 + exp(-y_i * x_i . w))``.

The regularization term ``C * w`` (an O(n) axpy) is applied by the Layer-2
model so the kernels stay pure data-term reductions.

Kernels MUST run with ``interpret=True``: this session's PJRT plugin is
CPU-only and real TPU lowering would emit a Mosaic custom-call it cannot
execute.  Interpret mode lowers the grid to plain HLO, which round-trips
through the HLO-text AOT path (see ``aot.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size.  100 divides every batch size used by the dataset registry
# (200/500/1000); odd batch sizes fall back to a single tile.
DEFAULT_TILE = 100


def _pick_tile(batch: int) -> int:
    """Largest row tile that exactly divides ``batch`` (no remainder blocks)."""
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    for tile in (256, 200, 128, DEFAULT_TILE, 64, 50, 32, 25, 16, 10, 8, 5, 4, 2):
        if batch % tile == 0 and tile <= batch:
            return tile
    return batch


def _grad_kernel(x_ref, y_ref, mask_ref, w_ref, scale_ref, o_ref):
    """One row tile: z = X@w; r = sigmoid(-y z) * (-y) * mask * scale; g += X^T r."""
    i = pl.program_id(0)
    x = x_ref[...]                      # (T, n) VMEM-resident row tile
    w = w_ref[...]                      # (n,)   resident across the grid
    z = x @ w                           # (T,)   first matvec (MXU)
    y = y_ref[...]
    m = mask_ref[...]
    s = jax.nn.sigmoid(-y * z)          # fused elementwise (VPU)
    r = (-y) * s * m * scale_ref[0]     # (T,)
    g = r @ x                           # (n,)   second matvec, same tile of X

    @pl.when(i == 0)
    def _init():
        o_ref[...] = g

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += g


def _loss_kernel(x_ref, y_ref, mask_ref, w_ref, o_ref):
    """One row tile of the masked logistic loss sum (numerically stable)."""
    i = pl.program_id(0)
    x = x_ref[...]
    w = w_ref[...]
    z = x @ w
    y = y_ref[...]
    m = mask_ref[...]
    # log(1 + exp(-yz)) == logaddexp(0, -yz): stable for large |yz|.
    loss = jnp.sum(jnp.logaddexp(0.0, -y * z) * m)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = loss[None]

    @pl.when(i > 0)
    def _acc():
        o_ref[...] += loss[None]


@functools.partial(jax.jit, static_argnames=("tile",))
def logreg_grad_data(x, y, mask, w, scale, tile: int | None = None):
    """Data term of the mini-batch logistic gradient via the Pallas kernel.

    Args:
      x:     (B, n) f32 mini-batch rows.
      y:     (B,)   f32 labels in {-1, +1} (padded rows: value irrelevant).
      mask:  (B,)   f32 1.0 for real rows, 0.0 for padding.
      w:     (n,)   f32 parameter vector.
      scale: (1,)   f32 normalization, typically 1/sum(mask).
      tile:  row-tile override (must divide B).

    Returns: (n,) f32 gradient data term (no regularization).
    """
    b, n = x.shape
    t = tile if tile is not None else _pick_tile(b)
    if b % t != 0:
        raise ValueError(f"tile {t} does not divide batch {b}")
    grid = (b // t,)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, n), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, y, mask, w, scale)


@functools.partial(jax.jit, static_argnames=("tile",))
def logreg_loss_sum(x, y, mask, w, tile: int | None = None):
    """Masked logistic loss sum over a mini-batch via the Pallas kernel.

    Returns: (1,) f32 — sum_i mask_i * log(1 + exp(-y_i x_i.w)).
    """
    b, n = x.shape
    t = tile if tile is not None else _pick_tile(b)
    if b % t != 0:
        raise ValueError(f"tile {t} does not divide batch {b}")
    grid = (b // t,)
    return pl.pallas_call(
        _loss_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, n), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x, y, mask, w)
