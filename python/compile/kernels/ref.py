"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in ``logreg.py`` must match these references (pytest +
hypothesis sweeps in ``python/tests/test_kernel.py``).  The rust native math
backend (``rust/src/math``) implements the same formulas and is cross-checked
against the AOT artifacts in rust integration tests, closing the loop:

    pallas kernel  ==  ref.py  ==  rust/src/math  ==  artifacts/*.hlo.txt
"""
from __future__ import annotations

import jax.numpy as jnp


def logreg_grad_data_ref(x, y, mask, w, scale):
    """(n,) data term: X^T( sigmoid(-y Xw) * (-y) * mask ) * scale."""
    z = x @ w
    s = 1.0 / (1.0 + jnp.exp(y * z))          # sigmoid(-y z)
    r = (-y) * s * mask * scale[0]
    return r @ x


def logreg_loss_sum_ref(x, y, mask, w):
    """(1,) masked logistic loss sum."""
    z = x @ w
    return jnp.sum(jnp.logaddexp(0.0, -y * z) * mask)[None]


def batch_grad_ref(w, x, y, mask, inv_cnt, c):
    """Full mini-batch gradient incl. l2 term: data_term + C w."""
    return logreg_grad_data_ref(x, y, mask, w, inv_cnt) + c[0] * w


def batch_obj_ref(w, x, y, mask, inv_cnt, c):
    """Mini-batch objective: mean masked loss + (C/2)||w||^2."""
    return logreg_loss_sum_ref(x, y, mask, w)[0] * inv_cnt[0] + 0.5 * c[0] * jnp.dot(w, w)
