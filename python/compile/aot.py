"""AOT compiler: lower every Layer-2 entrypoint to HLO *text* + manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

The shape grid below mirrors ``rust/src/data/registry.rs`` — one (batch,
features) combo per dataset/batch-size pair actually used by the experiment
harness.  ``manifest.json`` maps entrypoint x shape -> file + parameter
shapes so the rust runtime can load and type-check executables without
parsing HLO itself.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Shape grid — keep in sync with rust/src/data/registry.rs
# ---------------------------------------------------------------------------

#: feature dimensions of the scaled dataset stand-ins (DESIGN.md §3)
FEATURE_DIMS = (18, 22, 28, 54, 100, 128, 256, 512)

#: mini-batch sizes used by the tables (200/1000) and figures (500/1000);
#: 1000 doubles as the chunk size for full-dataset objective/gradient sweeps
BATCH_SIZES = (200, 500, 1000)

F32 = jnp.float32


def _vec(n):
    return jax.ShapeDtypeStruct((n,), F32)


def _mat(b, n):
    return jax.ShapeDtypeStruct((b, n), F32)


S1 = jax.ShapeDtypeStruct((1,), F32)


def entrypoints(b: int, n: int):
    """(name, fn, example_args) for every module lowered at shape (b, n)."""
    w, x, y, m = _vec(n), _mat(b, n), _vec(b), _vec(b)
    return [
        ("grad", model.batch_grad, (w, x, y, m, S1, S1)),
        ("obj", model.batch_obj, (w, x, y, m, S1, S1)),
        ("loss_sum", model.loss_sum, (w, x, y, m)),
        ("mbsgd", model.mbsgd_step, (w, x, y, m, S1, S1, S1)),
        ("sag", model.sag_step, (w, x, y, m, S1, S1, S1, w, w, S1)),
        ("saga", model.saga_step, (w, x, y, m, S1, S1, S1, w, w, S1)),
        ("svrg", model.svrg_step, (w, w, w, x, y, m, S1, S1, S1)),
        ("saag2", model.saag2_step, (w, x, y, m, S1, S1, S1, w, S1, S1)),
    ]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_list(args):
    return [list(a.shape) for a in args]


def lower_all(out_dir: str, dims, batches, quiet: bool = False) -> dict:
    manifest = {"format": "hlo-text", "dtype": "f32", "return_tuple": True,
                "entries": {}}
    todo = [(b, n) for n in dims for b in batches]
    t0 = time.time()
    for idx, (b, n) in enumerate(todo):
        for name, fn, args in entrypoints(b, n):
            key = f"{name}_B{b}_n{n}"
            fname = f"{key}.hlo.txt"
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["entries"][key] = {
                "entrypoint": name,
                "batch": b,
                "features": n,
                "file": fname,
                "param_shapes": shape_list(args),
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
        if not quiet:
            print(f"[aot] ({idx + 1}/{len(todo)}) B={b} n={n} "
                  f"({time.time() - t0:.1f}s elapsed)", file=sys.stderr)
    return manifest


def write_tsv(manifest: dict, out_dir: str) -> None:
    """The rust-side manifest: 6-column TSV (see rust/src/runtime/manifest.rs).

    Kept alongside manifest.json because the rust build is offline and
    dependency-minimal (no JSON parser); a TSV is the honest minimum.
    """
    lines = ["# samplex-manifest v1 format=hlo-text dtype=f32 return_tuple=1"]
    for key in sorted(manifest["entries"]):
        e = manifest["entries"][key]
        shapes = ",".join("x".join(str(d) for d in s) if s else "1"
                          for s in e["param_shapes"])
        lines.append(
            f"{key}\t{e['entrypoint']}\t{e['batch']}\t{e['features']}\t"
            f"{e['file']}\t{shapes}"
        )
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--dims", default=",".join(map(str, FEATURE_DIMS)),
                    help="comma-separated feature dims to lower")
    ap.add_argument("--batches", default=",".join(map(str, BATCH_SIZES)))
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    dims = [int(d) for d in args.dims.split(",") if d]
    batches = [int(b) for b in args.batches.split(",") if b]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = lower_all(args.out_dir, dims, batches, quiet=args.quiet)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    write_tsv(manifest, args.out_dir)
    print(f"[aot] wrote {len(manifest['entries'])} modules + manifest.{{json,tsv}} "
          f"to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
