"""Layer-2 JAX model: l2-regularized logistic ERM + fused solver update steps.

Every public function here is a pure, shape-static jax function suitable for
``jax.jit(...).lower(...)`` — ``aot.py`` lowers each one to HLO text per
(batch, features) shape used by the rust dataset registry, and the rust
coordinator executes them through PJRT.  Python never runs at training time.

Conventions (all f32):
  w        (n,)   parameter vector
  x        (B, n) mini-batch rows (padded to the static batch size)
  y        (B,)   labels in {-1, +1}
  mask     (B,)   1.0 real row / 0.0 padding — padding is *exact*, not
                  approximate: padded rows contribute zero loss and gradient
  inv_cnt  (1,)   1 / (number of real rows)   == 1/sum(mask)
  c        (1,)   l2 regularization coefficient C
  lr       (1,)   step size alpha
  inv_m    (1,)   1/m where m = number of mini-batches (SAG/SAGA/SAAG-II)

Solver state vectors (SAG/SAGA ``yj``/``avg``, SVRG ``mu``/``w_snap``,
SAAG-II ``acc``) are all (n,) and owned by the rust coordinator; the fused
steps return the refreshed state so the round trip is one PJRT call per
inner iteration.
"""
from __future__ import annotations

import jax.numpy as jnp

from .kernels.logreg import logreg_grad_data, logreg_loss_sum

__all__ = [
    "batch_grad",
    "batch_obj",
    "loss_sum",
    "mbsgd_step",
    "sag_step",
    "saga_step",
    "svrg_step",
    "saag2_step",
]


# --------------------------------------------------------------------------
# Core quantities
# --------------------------------------------------------------------------

def batch_grad(w, x, y, mask, inv_cnt, c):
    """Mini-batch gradient of eq.(3): (1/|B|) sum_i grad f_i(w) + C w."""
    return (logreg_grad_data(x, y, mask, w, inv_cnt) + c[0] * w,)


def batch_obj(w, x, y, mask, inv_cnt, c):
    """Mini-batch objective of eq.(3): mean masked logistic loss + (C/2)||w||^2.

    This is what the backtracking line search evaluates (paper §4.1: the
    line search is performed "approximately only using the selected
    mini-batch").
    """
    data = logreg_loss_sum(x, y, mask, w)[0] * inv_cnt[0]
    return (data + 0.5 * c[0] * jnp.dot(w, w),)


def loss_sum(w, x, y, mask):
    """Raw masked loss sum — rust chunks the full dataset through this to
    evaluate the eq.(2) objective (adds C/2||w||^2 and divides by l itself)."""
    return (logreg_loss_sum(x, y, mask, w)[0],)


def _g(w, x, y, mask, inv_cnt, c):
    return logreg_grad_data(x, y, mask, w, inv_cnt) + c[0] * w


# --------------------------------------------------------------------------
# Fused solver steps (one PJRT call per inner iteration)
# --------------------------------------------------------------------------

def mbsgd_step(w, x, y, mask, inv_cnt, c, lr):
    """MBSGD: w <- w - alpha * g_j(w)."""
    g = _g(w, x, y, mask, inv_cnt, c)
    return (w - lr[0] * g,)


def sag_step(w, x, y, mask, inv_cnt, c, lr, yj, avg, inv_m):
    """Mini-batch SAG (Schmidt et al. 2016, per-batch gradient memory):

        avg' = avg + (g_j(w) - y_j) / m
        y_j' = g_j(w)
        w'   = w - alpha * avg'

    Returns (w', y_j', avg').
    """
    g = _g(w, x, y, mask, inv_cnt, c)
    avg_new = avg + (g - yj) * inv_m[0]
    return (w - lr[0] * avg_new, g, avg_new)


def saga_step(w, x, y, mask, inv_cnt, c, lr, yj, avg, inv_m):
    """Mini-batch SAGA (Defazio et al. 2014):

        w'   = w - alpha * (g_j(w) - y_j + avg)
        avg' = avg + (g_j(w) - y_j) / m
        y_j' = g_j(w)

    Returns (w', y_j', avg').
    """
    g = _g(w, x, y, mask, inv_cnt, c)
    w_new = w - lr[0] * (g - yj + avg)
    avg_new = avg + (g - yj) * inv_m[0]
    return (w_new, g, avg_new)


def svrg_step(w, w_snap, mu, x, y, mask, inv_cnt, c, lr):
    """SVRG inner step (Johnson & Zhang 2013):

        w' = w - alpha * (g_j(w) - g_j(w_snap) + mu)

    ``mu`` is the full gradient at the snapshot, maintained by rust via the
    chunked ``batch_grad`` entrypoint.  Reads the same X tile twice through
    the kernel — still one HBM pass per matvec pair, fused in one module.
    """
    g = _g(w, x, y, mask, inv_cnt, c)
    g_snap = _g(w_snap, x, y, mask, inv_cnt, c)
    return (w - lr[0] * (g - g_snap + mu),)


def saag2_step(w, x, y, mask, inv_cnt, c, lr, acc, coeff, inv_m):
    """SAAG-II (reconstruction of Chauhan et al., ACML 2017 — paper ref [3]).

    Epoch-accumulated adjusted average: with ``acc = sum_{k<j} g_k(w^k)`` over
    the current epoch and ``coeff = (m - j)/m``:

        d_j  = acc/m + coeff * g_j(w)        (biased epoch average, the
                                              remaining m-j batches proxied
                                              by the current gradient)
        acc' = acc + g_j(w)
        w'   = w - alpha * d_j

    Returns (w', acc').  Rust resets ``acc`` to zero at each epoch start.
    See DESIGN.md §6 for why a faithful-behaviour reconstruction suffices.
    """
    g = _g(w, x, y, mask, inv_cnt, c)
    d = acc * inv_m[0] + coeff[0] * g
    return (w - lr[0] * d, acc + g)
