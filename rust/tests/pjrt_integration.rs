//! Integration: the AOT JAX/Pallas artifacts executed through PJRT must
//! agree with the native Rust math to f32 tolerance — this closes the
//! `pallas == ref.py == rust == artifacts` correctness loop from the rust
//! side (the python side is closed by pytest).
//!
//! All tests no-op with a note if `artifacts/` is absent (run
//! `make artifacts` first); CI always builds artifacts before `cargo test`.

use samplex::backend::{ComputeBackend, FusedStep, NativeBackend, PjrtBackend};
use samplex::data::batch::BatchView;
use samplex::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.tsv").is_file().then_some(p)
}

fn toy(rows: usize, cols: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::seed_from(seed);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32 * 0.7).collect();
    let y: Vec<f32> = (0..rows)
        .map(|r| {
            let z: f32 = (0..cols).map(|k| x[r * cols + k]).sum();
            if z >= 0.0 {
                1.0
            } else {
                -1.0
            }
        })
        .collect();
    let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.2).collect();
    (x, y, w)
}

const N: usize = 28; // higgs-mini feature dim — present in the AOT grid

#[test]
fn pjrt_grad_matches_native() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping (no artifacts)");
        return;
    };
    let mut pjrt = PjrtBackend::new(&dir, N, 200).unwrap();
    let mut native = NativeBackend::new();
    for rows in [200usize, 137, 1] {
        let (x, y, w) = toy(rows, N, rows as u64);
        let view = BatchView::dense(&x, &y, N);
        let mut g_p = vec![0f32; N];
        let mut g_n = vec![0f32; N];
        pjrt.grad_into(&w, &view, 0.01, &mut g_p).unwrap();
        native.grad_into(&w, &view, 0.01, &mut g_n).unwrap();
        for k in 0..N {
            assert!(
                (g_p[k] - g_n[k]).abs() < 1e-4 * (1.0 + g_n[k].abs()),
                "rows={rows} k={k}: pjrt={} native={}",
                g_p[k],
                g_n[k]
            );
        }
    }
}

#[test]
fn pjrt_objective_and_loss_match_native() {
    let Some(dir) = artifacts() else {
        return;
    };
    let mut pjrt = PjrtBackend::new(&dir, N, 200).unwrap();
    let mut native = NativeBackend::new();
    let (x, y, w) = toy(450, N, 9); // forces loss_sum chunking (450 > 200)
    let view = BatchView::dense(&x, &y, N);
    let o_p = pjrt.batch_obj(&w, &BatchView::dense(&x[..200 * N], &y[..200], N), 0.05).unwrap();
    let o_n = native.batch_obj(&w, &BatchView::dense(&x[..200 * N], &y[..200], N), 0.05).unwrap();
    assert!((o_p - o_n).abs() < 1e-4 * (1.0 + o_n.abs()), "obj: {o_p} vs {o_n}");
    let l_p = pjrt.loss_sum(&w, &view).unwrap();
    let l_n = native.loss_sum(&w, &view).unwrap();
    assert!((l_p - l_n).abs() < 1e-3 * (1.0 + l_n.abs()), "loss: {l_p} vs {l_n}");
}

#[test]
fn pjrt_full_objective_matches_native() {
    let Some(dir) = artifacts() else {
        return;
    };
    let (x, y, w) = toy(1500, N, 4);
    let ds: samplex::data::Dataset =
        samplex::data::dense::DenseDataset::new("t", N, x, y).unwrap().into();
    let mut pjrt = PjrtBackend::new(&dir, N, 1000).unwrap();
    let mut native = NativeBackend::new();
    let a = pjrt.full_objective(&w, &ds, 1e-3).unwrap();
    let b = native.full_objective(&w, &ds, 1e-3).unwrap();
    assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
}

#[test]
fn fused_steps_match_composed_updates() {
    let Some(dir) = artifacts() else {
        return;
    };
    let mut pjrt = PjrtBackend::new(&dir, N, 200).unwrap();
    let mut native = NativeBackend::new();
    let (x, y, w0) = toy(200, N, 77);
    let view = BatchView::dense(&x, &y, N);
    let c = 0.01f32;
    let lr = 0.05f32;
    let tol = |a: f32, b: f32| (a - b).abs() < 2e-4 * (1.0 + b.abs());

    // MBSGD
    let mut w = w0.clone();
    assert!(pjrt.fused(FusedStep::Mbsgd { w: &mut w, lr }, &view, c).unwrap());
    let mut g = vec![0f32; N];
    native.grad_into(&w0, &view, c, &mut g).unwrap();
    for k in 0..N {
        assert!(tol(w[k], w0[k] - lr * g[k]), "mbsgd k={k}");
    }

    // SAG
    let mut rng = Rng::seed_from(5);
    let yj0: Vec<f32> = (0..N).map(|_| rng.normal() as f32 * 0.1).collect();
    let avg0: Vec<f32> = (0..N).map(|_| rng.normal() as f32 * 0.1).collect();
    let (mut w, mut yj, mut avg) = (w0.clone(), yj0.clone(), avg0.clone());
    assert!(pjrt
        .fused(FusedStep::Sag { w: &mut w, yj: &mut yj, avg: &mut avg, lr, inv_m: 0.25 }, &view, c)
        .unwrap());
    for k in 0..N {
        let avg_want = avg0[k] + (g[k] - yj0[k]) * 0.25;
        assert!(tol(avg[k], avg_want), "sag avg k={k}");
        assert!(tol(yj[k], g[k]), "sag yj k={k}");
        assert!(tol(w[k], w0[k] - lr * avg_want), "sag w k={k}");
    }

    // SAGA
    let (mut w, mut yj, mut avg) = (w0.clone(), yj0.clone(), avg0.clone());
    assert!(pjrt
        .fused(FusedStep::Saga { w: &mut w, yj: &mut yj, avg: &mut avg, lr, inv_m: 0.25 }, &view, c)
        .unwrap());
    for k in 0..N {
        assert!(tol(w[k], w0[k] - lr * (g[k] - yj0[k] + avg0[k])), "saga w k={k}");
        assert!(tol(avg[k], avg0[k] + (g[k] - yj0[k]) * 0.25), "saga avg k={k}");
    }

    // SVRG
    let w_snap: Vec<f32> = (0..N).map(|k| w0[k] * 0.5).collect();
    let mu: Vec<f32> = (0..N).map(|k| yj0[k] * 0.3).collect();
    let mut w = w0.clone();
    assert!(pjrt
        .fused(FusedStep::Svrg { w: &mut w, w_snap: &w_snap, mu: &mu, lr }, &view, c)
        .unwrap());
    let mut g_snap = vec![0f32; N];
    native.grad_into(&w_snap, &view, c, &mut g_snap).unwrap();
    for k in 0..N {
        assert!(tol(w[k], w0[k] - lr * (g[k] - g_snap[k] + mu[k])), "svrg k={k}");
    }

    // SAAG-II
    let acc0 = yj0.clone();
    let (mut w, mut acc) = (w0.clone(), acc0.clone());
    assert!(pjrt
        .fused(
            FusedStep::Saag2 { w: &mut w, acc: &mut acc, lr, coeff: 0.75, inv_m: 0.25 },
            &view,
            c
        )
        .unwrap());
    for k in 0..N {
        let d = acc0[k] * 0.25 + 0.75 * g[k];
        assert!(tol(w[k], w0[k] - lr * d), "saag2 w k={k}");
        assert!(tol(acc[k], acc0[k] + g[k]), "saag2 acc k={k}");
    }
}

#[test]
fn ragged_batch_padding_is_exact() {
    let Some(dir) = artifacts() else {
        return;
    };
    // rows < static batch: the masked artifacts must equal native math on
    // the un-padded rows exactly (same formula, same data)
    let mut pjrt = PjrtBackend::new(&dir, N, 200).unwrap();
    let mut native = NativeBackend::new();
    let (x, y, w) = toy(73, N, 21);
    let view = BatchView::dense(&x, &y, N);
    let mut g_p = vec![0f32; N];
    let mut g_n = vec![0f32; N];
    pjrt.grad_into(&w, &view, 0.1, &mut g_p).unwrap();
    native.grad_into(&w, &view, 0.1, &mut g_n).unwrap();
    for k in 0..N {
        assert!((g_p[k] - g_n[k]).abs() < 1e-4 * (1.0 + g_n[k].abs()), "k={k}");
    }
}

#[test]
fn end_to_end_train_pjrt_vs_native_same_trajectory() {
    let Some(_dir) = artifacts() else {
        return;
    };
    use samplex::config::{BackendKind, ExperimentConfig};
    use samplex::sampling::SamplingKind;
    use samplex::solvers::SolverKind;

    let ds: samplex::data::Dataset = samplex::data::synth::generate(
        &samplex::data::synth::SynthSpec {
            name: "it",
            rows: 1000,
            cols: N,
            dist: samplex::data::synth::FeatureDist::Gaussian,
            flip_prob: 0.05,
            margin_noise: 0.3,
            pos_fraction: 0.5,
        },
        11,
    )
    .unwrap()
    .into();

    let mut cfg = ExperimentConfig::quick("it", SolverKind::Saga, SamplingKind::Ss, 200);
    cfg.epochs = 2;
    cfg.reg_c = Some(1e-3);
    cfg.artifacts_dir =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").display().to_string();

    cfg.backend = BackendKind::Native;
    let r_native = samplex::train::run_experiment(&cfg, &ds).unwrap();
    cfg.backend = BackendKind::Pjrt;
    let r_pjrt = samplex::train::run_experiment(&cfg, &ds).unwrap();

    // same selections, numerics within f32 dispatch noise
    assert!(
        (r_native.final_objective - r_pjrt.final_objective).abs()
            < 1e-3 * (1.0 + r_native.final_objective.abs()),
        "native={} pjrt={}",
        r_native.final_objective,
        r_pjrt.final_objective
    );
    // both must actually have descended
    assert!(r_pjrt.final_objective < r_pjrt.trace.points[0].objective);
}
