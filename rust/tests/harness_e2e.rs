//! End-to-end harness tests: small-scale versions of the paper's tables and
//! figures must reproduce the paper's qualitative *shape*:
//!
//! * CS/SS train faster than RS at equal epochs (Tables 2–4 shape);
//! * objectives agree between samplings to several decimals (the paper:
//!   "values are same up to certain decimal places");
//! * speedup grows with the storage profile's positioning cost
//!   (HDD > SSD > RAM — paper §1: "more prominent for HDD");
//! * Theorem 1 shape: all three samplings converge linearly at comparable
//!   empirical rates.

use samplex::backend::NativeBackend;
use samplex::bench_harness::{run_figure, run_table, speedups};
use samplex::config::{ExperimentConfig, GridConfig, StepKind};
use samplex::data::synth::{generate, FeatureDist, SynthSpec};
use samplex::sampling::{Sampler, SamplingKind};
use samplex::solvers::SolverKind;
use samplex::train::estimate_optimum;

fn dataset(rows: usize, cols: usize, seed: u64) -> samplex::data::Dataset {
    generate(
        &SynthSpec {
            name: "e2e",
            rows,
            cols,
            dist: FeatureDist::Gaussian,
            flip_prob: 0.08,
            margin_noise: 0.5,
            pos_fraction: 0.5,
        },
        seed,
    )
    .unwrap()
    .into()
}

fn small_grid(epochs: usize) -> GridConfig {
    let mut g = GridConfig::paper_table("e2e");
    g.base.epochs = epochs;
    g.base.reg_c = Some(1e-3);
    // test datasets are tiny (≪ any real cache); model the paper's
    // data-larger-than-cache regime with a cold hdd, where the access-cost
    // ordering is most pronounced and the shape assertion is robust
    g.base.storage.profile = "hdd".into();
    g.base.storage.cache_mib = 0;
    g.solvers = vec![SolverKind::Mbsgd, SolverKind::Sag, SolverKind::Svrg];
    g.batch_sizes = vec![100];
    g.steps = vec![StepKind::Constant];
    g
}

#[test]
fn table_shape_cs_ss_faster_same_objective() {
    let ds = dataset(3000, 12, 3);
    let rows = run_table(&small_grid(3), &ds, None).unwrap();
    assert_eq!(rows.len(), 9); // 3 solvers x 3 samplings

    for sp in speedups(&rows) {
        assert!(sp.cs > 1.5, "{}: RS/CS = {:.2} (want > 1.5)", sp.setting, sp.cs);
        assert!(sp.ss > 1.5, "{}: RS/SS = {:.2} (want > 1.5)", sp.setting, sp.ss);
    }

    // objectives agree across samplings to ~2+ decimals per solver
    let mut by_solver: std::collections::HashMap<&str, Vec<f64>> = Default::default();
    for r in &rows {
        by_solver.entry(r.solver.as_str()).or_default().push(r.objective);
    }
    for (solver, objs) in by_solver {
        let min = objs.iter().cloned().fold(f64::MAX, f64::min);
        let max = objs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(
            max - min < 0.05 * (1.0 + min.abs()),
            "{solver}: objective spread {min}..{max} too wide"
        );
    }
}

#[test]
fn speedup_is_most_prominent_on_hdd() {
    // paper §1: "the difference in access time would be more prominent for
    // HDD" — the RS/SS time ratio must be ordered hdd > ssd >= ram
    let ds = dataset(3000, 12, 5);
    let mut ratios = Vec::new();
    for profile in ["hdd", "ssd", "ram"] {
        let mut g = small_grid(2);
        g.solvers = vec![SolverKind::Mbsgd];
        g.base.storage.profile = profile.into();
        let rows = run_table(&g, &ds, None).unwrap();
        let sp = speedups(&rows);
        assert_eq!(sp.len(), 1);
        ratios.push((profile, sp[0].ss));
    }
    assert!(
        ratios[0].1 > ratios[1].1,
        "hdd speedup {} should exceed ssd {}",
        ratios[0].1,
        ratios[1].1
    );
    assert!(
        ratios[1].1 >= ratios[2].1 * 0.9,
        "ssd speedup {} should be >= ram {}",
        ratios[1].1,
        ratios[2].1
    );
}

#[test]
fn theorem1_shape_linear_convergence_all_samplings() {
    let ds = dataset(2000, 10, 7);
    let mut be = NativeBackend::new();
    let p_star = estimate_optimum(&mut be, &ds, 1e-3, 1500).unwrap();

    let mut g = small_grid(8);
    g.solvers = vec![SolverKind::Mbsgd];
    let series = run_figure(&g, &ds, p_star, None).unwrap();
    assert_eq!(series.len(), 3);

    let mut rates = std::collections::HashMap::new();
    for s in &series {
        let rate = s.rate.unwrap_or(0.0);
        assert!(
            rate < -0.01,
            "{}: expected clearly negative log-gap slope, got {rate}",
            s.label
        );
        rates.insert(s.sampling, rate);
    }
    // same order of magnitude across samplings (Theorem 1: same rate in
    // expectation)
    let rs = rates[&SamplingKind::Rs];
    let cs = rates[&SamplingKind::Cs];
    let ss = rates[&SamplingKind::Ss];
    for (name, r) in [("cs", cs), ("ss", ss)] {
        let ratio = r / rs;
        assert!(
            (0.2..5.0).contains(&ratio),
            "{name} rate {r} vs rs rate {rs}: ratio {ratio} out of family"
        );
    }
}

#[test]
fn line_search_arms_run_and_cost_more_compute() {
    let ds = dataset(1500, 8, 9);
    let mk = |step: StepKind| {
        let mut cfg = ExperimentConfig::quick("e2e", SolverKind::Mbsgd, SamplingKind::Ss, 100);
        cfg.epochs = 2;
        cfg.reg_c = Some(1e-3);
        cfg.step = step;
        samplex::train::run_experiment(&cfg, &ds).unwrap()
    };
    let constant = mk(StepKind::Constant);
    let ls = mk(StepKind::LineSearch);
    assert!(
        ls.time.compute_s > constant.time.compute_s,
        "line search must pay extra objective evaluations ({} !> {})",
        ls.time.compute_s,
        constant.time.compute_s
    );
    // both still descend
    assert!(constant.final_objective < constant.trace.points[0].objective);
    assert!(ls.final_objective < ls.trace.points[0].objective);
}

#[test]
fn rswr_and_stratified_extension_arms_run() {
    let ds = dataset(1000, 8, 13);
    for kind in [SamplingKind::Rswr, SamplingKind::Stratified] {
        let mut cfg = ExperimentConfig::quick("e2e", SolverKind::Mbsgd, kind, 100);
        cfg.epochs = 2;
        cfg.reg_c = Some(1e-3);
        let r = samplex::train::run_experiment(&cfg, &ds).unwrap();
        assert!(
            r.final_objective < r.trace.points[0].objective,
            "{} should descend",
            kind.label()
        );
    }
}

#[test]
fn out_of_core_disk_training_matches_in_memory() {
    // train out-of-core through DiskSource + prefetcher-style owned batches
    // by resolving from a saved .sxb, and compare with in-memory training
    let ds = dataset(1200, 8, 17);
    let dir = std::env::temp_dir().join(format!("sx_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("e2e.sxb");
    ds.save(&path).unwrap();

    let mut src = samplex::storage::reader::DiskSource::open(&path).unwrap();
    assert_eq!(src.rows(), 1200);

    // read a full epoch of SS batches from disk; gradient-descend natively
    let mut sampler: Box<dyn Sampler> = SamplingKind::Ss.build(1200, 100, 1, None).unwrap();
    let mut w_disk = vec![0f32; 8];
    let mut g = vec![0f32; 8];
    let mut xbuf = Vec::new();
    let mut ybuf = Vec::new();
    for sel in sampler.epoch(0) {
        src.read_selection(&sel, &mut xbuf, &mut ybuf).unwrap();
        samplex::math::grad_into(&w_disk, &xbuf, &ybuf, 8, 1e-3, &mut g);
        samplex::math::axpy(-0.1, &g, &mut w_disk);
    }

    // identical updates from memory
    let mut sampler2: Box<dyn Sampler> = SamplingKind::Ss.build(1200, 100, 1, None).unwrap();
    let mut w_mem = vec![0f32; 8];
    let mut asm = samplex::data::batch::BatchAssembler::new();
    for sel in sampler2.epoch(0) {
        let view = asm.assemble(&ds, &sel).unwrap();
        let dv = view.as_dense().unwrap();
        samplex::math::grad_into(&w_mem, dv.x, dv.y, 8, 1e-3, &mut g);
        samplex::math::axpy(-0.1, &g, &mut w_mem);
    }
    assert_eq!(w_disk, w_mem, "disk-backed epoch must be bit-identical");
    std::fs::remove_dir_all(&dir).ok();
}
