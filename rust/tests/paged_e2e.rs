//! End-to-end out-of-core tests: the paged data plane must train every
//! solver with trajectories **bit-identical** to the in-core stores, under
//! page budgets from a single page up to the whole file, while really
//! evicting and re-faulting pages (proven by `IoStats.bytes_read` far
//! exceeding the budget) and reproducing the paper's contiguous-vs-
//! dispersed gap in page-fault counts on real file I/O.
//!
//! The CI out-of-core job runs exactly this file:
//! `cargo test --release --test paged_e2e`.

use samplex::config::ExperimentConfig;
use samplex::data::batch::BatchAssembler;
use samplex::data::synth::{self, FeatureDist, SparseSynthSpec, SynthSpec};
use samplex::data::{Dataset, PagedDataset};
use samplex::sampling::{Sampler, SamplingKind};
use samplex::solvers::SolverKind;
use samplex::train::run_experiment;

static UNIQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

fn tmp_path(ext: &str) -> std::path::PathBuf {
    let uniq = UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("paged_e2e_{}_{uniq}.{ext}", std::process::id()))
}

fn dense_ds(rows: usize, cols: usize, seed: u64) -> Dataset {
    synth::generate(
        &SynthSpec {
            name: "ooc",
            rows,
            cols,
            dist: FeatureDist::Gaussian,
            flip_prob: 0.05,
            margin_noise: 0.3,
            pos_fraction: 0.5,
        },
        seed,
    )
    .unwrap()
    .into()
}

fn csr_ds(rows: usize, seed: u64) -> Dataset {
    Dataset::Csr(
        synth::generate_csr(
            &SparseSynthSpec {
                name: "ooc-sparse",
                rows,
                cols: 5_000,
                nnz_per_row: 20,
                flip_prob: 0.05,
                margin_noise: 0.3,
                pos_fraction: 0.5,
            },
            seed,
        )
        .unwrap(),
    )
}

/// Save `ds` to a temp binary and reopen it paged at the given budget.
fn paged_copy(ds: &Dataset, budget_bytes: u64, page_bytes: u64) -> (std::path::PathBuf, Dataset) {
    let ext = if ds.is_csr() { "sxc" } else { "sxb" };
    let p = tmp_path(ext);
    ds.save(&p).unwrap();
    let paged: Dataset = PagedDataset::open(&p, budget_bytes, page_bytes).unwrap().into();
    (p, paged)
}

fn cfg(solver: SolverKind, sampling: SamplingKind, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("ooc", solver, sampling, batch);
    c.epochs = 2;
    c.reg_c = Some(1e-3);
    c.record_every = 1;
    c
}

/// Acceptance criterion: a 120k-row synthetic trains end-to-end through
/// all five solvers at a page budget of ≤ 25% of the file size, through
/// the prefetch pipeline, bit-identical to the in-core run.
#[test]
fn all_five_solvers_bit_identical_at_quarter_budget_120k_rows() {
    let ds = dense_ds(120_000, 8, 11);
    let budget = ds.file_bytes() / 4;
    let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
    assert!(paged.as_paged().unwrap().budget_bytes() < ds.file_bytes());
    for solver in SolverKind::all() {
        let mut c = cfg(solver, SamplingKind::Ss, 2000);
        c.prefetch_depth = 2;
        let incore = run_experiment(&c, &ds).unwrap();
        let ooc = run_experiment(&c, &paged).unwrap();
        assert_eq!(incore.w, ooc.w, "{}: iterates must be bit-identical", solver.label());
        assert_eq!(
            incore.final_objective.to_bits(),
            ooc.final_objective.to_bits(),
            "{}: objective must be bit-identical",
            solver.label()
        );
        assert!(ooc.time.io.bytes_read > 0, "{}: must really read the file", solver.label());
    }
    std::fs::remove_file(path).ok();
}

/// Satellite: SAGA and SVRG trajectories on `PagedDataset` are
/// bit-identical to `DenseDataset`/`CsrDataset` for all five sampler kinds
/// at page budgets {1 page, 25%, 100%}.
#[test]
fn saga_svrg_trajectories_match_incore_for_all_samplers_and_budgets() {
    let page_bytes = 2048u64;
    let all_samplers = [
        SamplingKind::Rs,
        SamplingKind::Rswr,
        SamplingKind::Cs,
        SamplingKind::Ss,
        SamplingKind::Stratified,
    ];
    for ds in [dense_ds(2400, 6, 3), csr_ds(1500, 4)] {
        let layout = if ds.is_csr() { "csr" } else { "dense" };
        for solver in [SolverKind::Saga, SolverKind::Svrg] {
            for sampling in all_samplers {
                let c = cfg(solver, sampling, 100);
                let incore = run_experiment(&c, &ds).unwrap();
                for budget in [page_bytes, ds.file_bytes() / 4, ds.file_bytes()] {
                    let (path, paged) = paged_copy(&ds, budget, page_bytes);
                    let ooc = run_experiment(&c, &paged).unwrap();
                    assert_eq!(
                        incore.w,
                        ooc.w,
                        "{layout}/{}/{} budget={budget}",
                        solver.label(),
                        sampling.label()
                    );
                    assert_eq!(
                        incore.final_objective.to_bits(),
                        ooc.final_objective.to_bits(),
                        "{layout}/{}/{} budget={budget}",
                        solver.label(),
                        sampling.label()
                    );
                    std::fs::remove_file(path).ok();
                }
            }
        }
    }
}

/// Satellite / CI assertion: with a budget far below the file size, the
/// e2e run must evict and re-fault pages — lifetime `bytes_read` strictly
/// exceeds the budget (a store that merely cached everything could never
/// read more than budget + one cold pass).
#[test]
fn tiny_budget_forces_evictions_bytes_read_exceeds_budget() {
    let ds = dense_ds(120_000, 8, 7);
    let budget = 4 * 64 * 1024u64; // 256 KiB pool vs a ~3.8 MiB file
    assert!(budget < ds.file_bytes() / 4);
    let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
    let mut c = cfg(SolverKind::Mbsgd, SamplingKind::Cs, 2000);
    c.epochs = 3;
    c.prefetch_depth = 2;
    let report = run_experiment(&c, &paged).unwrap();
    let io = report.time.io;
    assert!(
        io.bytes_read > budget,
        "eviction proof failed: read {} bytes within a {budget}-byte budget",
        io.bytes_read
    );
    // 3 epochs + objective sweeps over a thrashing pool: well beyond one
    // cold pass of the file as well
    assert!(io.bytes_read > ds.file_bytes(), "must re-read evicted pages");
    assert!(io.page_faults > 0 && io.read_calls > 0);
    std::fs::remove_file(path).ok();
}

/// Acceptance criterion: below a 100% budget, contiguous CS/SS epochs take
/// strictly fewer page faults than scattered RS epochs — the paper's gap
/// on real file I/O.
#[test]
fn cs_and_ss_fault_strictly_less_than_rs_below_full_budget() {
    let ds = dense_ds(50_000, 8, 5);
    for budget_pct in [10u64, 25, 50] {
        let budget = ds.file_bytes() * budget_pct / 100;
        let faults = |kind: SamplingKind| {
            let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
            let mut sampler: Box<dyn Sampler> = kind.build(50_000, 500, 7, None).unwrap();
            let mut asm = BatchAssembler::new();
            for e in 0..2 {
                for sel in sampler.epoch(e) {
                    std::hint::black_box(asm.assemble(&paged, &sel).rows());
                }
            }
            let io = paged.io_stats();
            std::fs::remove_file(path).ok();
            io.page_faults
        };
        let (rs, cs, ss) = (
            faults(SamplingKind::Rs),
            faults(SamplingKind::Cs),
            faults(SamplingKind::Ss),
        );
        assert!(cs < rs, "budget {budget_pct}%: cs faults {cs} !< rs faults {rs}");
        assert!(ss < rs, "budget {budget_pct}%: ss faults {ss} !< rs faults {rs}");
    }
}

/// The paged path composes with the data-parallel trainer (§5): shards
/// assemble out of the shared store and converge like the in-core run.
#[test]
fn data_parallel_trains_out_of_core() {
    let ds = dense_ds(4000, 6, 9);
    let (path, paged) = paged_copy(&ds, ds.file_bytes() / 4, 4096);
    let c = cfg(SolverKind::Mbsgd, SamplingKind::Cs, 100);
    let par_incore = samplex::train::parallel::run_data_parallel(&c, &ds, 3).unwrap();
    let par_paged = samplex::train::parallel::run_data_parallel(&c, &paged, 3).unwrap();
    assert_eq!(par_incore.w, par_paged.w, "parallel shards must match bit for bit");
    assert!(paged.io_stats().bytes_read > 0);
    std::fs::remove_file(path).ok();
}
