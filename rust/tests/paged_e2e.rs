//! End-to-end out-of-core tests: the paged data plane must train every
//! solver with trajectories **bit-identical** to the in-core stores, under
//! page budgets from a single page up to the whole file, while really
//! evicting and re-faulting pages (proven by `IoStats.bytes_read` far
//! exceeding the budget) and reproducing the paper's contiguous-vs-
//! dispersed gap in page-fault counts on real file I/O.
//!
//! The CI out-of-core job runs exactly this file:
//! `cargo test --release --test paged_e2e`.

use std::sync::Arc;

use samplex::config::ExperimentConfig;
use samplex::data::batch::BatchAssembler;
use samplex::data::synth::{self, FeatureDist, SparseSynthSpec, SynthSpec};
use samplex::data::{Dataset, PagedDataset};
use samplex::pipeline::prefetch::Prefetcher;
use samplex::sampling::{Sampler, SamplingKind};
use samplex::solvers::SolverKind;
use samplex::storage::pagestore::Readahead;
use samplex::storage::profile::DeviceProfile;
use samplex::storage::simulator::AccessSimulator;
use samplex::train::run_experiment;

static UNIQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

fn tmp_path(ext: &str) -> std::path::PathBuf {
    let uniq = UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("paged_e2e_{}_{uniq}.{ext}", std::process::id()))
}

fn dense_ds(rows: usize, cols: usize, seed: u64) -> Dataset {
    synth::generate(
        &SynthSpec {
            name: "ooc",
            rows,
            cols,
            dist: FeatureDist::Gaussian,
            flip_prob: 0.05,
            margin_noise: 0.3,
            pos_fraction: 0.5,
        },
        seed,
    )
    .unwrap()
    .into()
}

fn csr_ds(rows: usize, seed: u64) -> Dataset {
    Dataset::Csr(
        synth::generate_csr(
            &SparseSynthSpec {
                name: "ooc-sparse",
                rows,
                cols: 5_000,
                nnz_per_row: 20,
                flip_prob: 0.05,
                margin_noise: 0.3,
                pos_fraction: 0.5,
            },
            seed,
        )
        .unwrap(),
    )
}

/// Save `ds` to a temp binary and reopen it paged at the given budget.
fn paged_copy(ds: &Dataset, budget_bytes: u64, page_bytes: u64) -> (std::path::PathBuf, Dataset) {
    let ext = if ds.is_csr() { "sxc" } else { "sxb" };
    let p = tmp_path(ext);
    ds.save(&p).unwrap();
    let paged: Dataset = PagedDataset::open(&p, budget_bytes, page_bytes).unwrap().into();
    (p, paged)
}

fn cfg(solver: SolverKind, sampling: SamplingKind, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("ooc", solver, sampling, batch);
    c.epochs = 2;
    c.reg_c = Some(1e-3);
    c.record_every = 1;
    c
}

/// Acceptance criterion: a 120k-row synthetic trains end-to-end through
/// all five solvers at a page budget of ≤ 25% of the file size, through
/// the prefetch pipeline, bit-identical to the in-core run.
#[test]
fn all_five_solvers_bit_identical_at_quarter_budget_120k_rows() {
    let ds = dense_ds(120_000, 8, 11);
    let budget = ds.file_bytes() / 4;
    let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
    assert!(paged.as_paged().unwrap().budget_bytes() < ds.file_bytes());
    for solver in SolverKind::all() {
        let mut c = cfg(solver, SamplingKind::Ss, 2000);
        c.prefetch_depth = 2;
        let incore = run_experiment(&c, &ds).unwrap();
        let ooc = run_experiment(&c, &paged).unwrap();
        assert_eq!(incore.w, ooc.w, "{}: iterates must be bit-identical", solver.label());
        assert_eq!(
            incore.final_objective.to_bits(),
            ooc.final_objective.to_bits(),
            "{}: objective must be bit-identical",
            solver.label()
        );
        assert!(ooc.time.io.bytes_read > 0, "{}: must really read the file", solver.label());
    }
    std::fs::remove_file(path).ok();
}

/// Satellite: SAGA and SVRG trajectories on `PagedDataset` are
/// bit-identical to `DenseDataset`/`CsrDataset` for all five sampler kinds
/// at page budgets {1 page, 25%, 100%}.
#[test]
fn saga_svrg_trajectories_match_incore_for_all_samplers_and_budgets() {
    let page_bytes = 2048u64;
    let all_samplers = [
        SamplingKind::Rs,
        SamplingKind::Rswr,
        SamplingKind::Cs,
        SamplingKind::Ss,
        SamplingKind::Stratified,
    ];
    for ds in [dense_ds(2400, 6, 3), csr_ds(1500, 4)] {
        let layout = if ds.is_csr() { "csr" } else { "dense" };
        for solver in [SolverKind::Saga, SolverKind::Svrg] {
            for sampling in all_samplers {
                let c = cfg(solver, sampling, 100);
                let incore = run_experiment(&c, &ds).unwrap();
                for budget in [page_bytes, ds.file_bytes() / 4, ds.file_bytes()] {
                    let (path, paged) = paged_copy(&ds, budget, page_bytes);
                    let ooc = run_experiment(&c, &paged).unwrap();
                    assert_eq!(
                        incore.w,
                        ooc.w,
                        "{layout}/{}/{} budget={budget}",
                        solver.label(),
                        sampling.label()
                    );
                    assert_eq!(
                        incore.final_objective.to_bits(),
                        ooc.final_objective.to_bits(),
                        "{layout}/{}/{} budget={budget}",
                        solver.label(),
                        sampling.label()
                    );
                    std::fs::remove_file(path).ok();
                }
            }
        }
    }
}

/// Satellite / CI assertion: with a budget far below the file size, the
/// e2e run must evict and re-fault pages — lifetime `bytes_read` strictly
/// exceeds the budget (a store that merely cached everything could never
/// read more than budget + one cold pass).
#[test]
fn tiny_budget_forces_evictions_bytes_read_exceeds_budget() {
    let ds = dense_ds(120_000, 8, 7);
    let budget = 4 * 64 * 1024u64; // 256 KiB pool vs a ~3.8 MiB file
    assert!(budget < ds.file_bytes() / 4);
    let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
    let mut c = cfg(SolverKind::Mbsgd, SamplingKind::Cs, 2000);
    c.epochs = 3;
    c.prefetch_depth = 2;
    let report = run_experiment(&c, &paged).unwrap();
    let io = report.time.io;
    assert!(
        io.bytes_read > budget,
        "eviction proof failed: read {} bytes within a {budget}-byte budget",
        io.bytes_read
    );
    // 3 epochs + objective sweeps over a thrashing pool: well beyond one
    // cold pass of the file as well
    assert!(io.bytes_read > ds.file_bytes(), "must re-read evicted pages");
    assert!(io.page_faults > 0 && io.read_calls > 0);
    std::fs::remove_file(path).ok();
}

/// Acceptance criterion: below a 100% budget, contiguous CS/SS epochs take
/// strictly fewer page faults than scattered RS epochs — the paper's gap
/// on real file I/O.
#[test]
fn cs_and_ss_fault_strictly_less_than_rs_below_full_budget() {
    let ds = dense_ds(50_000, 8, 5);
    for budget_pct in [10u64, 25, 50] {
        let budget = ds.file_bytes() * budget_pct / 100;
        let faults = |kind: SamplingKind| {
            let (path, paged) = paged_copy(&ds, budget, 64 * 1024);
            let mut sampler: Box<dyn Sampler> = kind.build(50_000, 500, 7, None).unwrap();
            let mut asm = BatchAssembler::new();
            for e in 0..2 {
                for sel in sampler.epoch(e) {
                    std::hint::black_box(asm.assemble(&paged, &sel).unwrap().rows());
                }
            }
            let io = paged.io_stats();
            std::fs::remove_file(path).ok();
            io.page_faults
        };
        let (rs, cs, ss) = (
            faults(SamplingKind::Rs),
            faults(SamplingKind::Cs),
            faults(SamplingKind::Ss),
        );
        assert!(cs < rs, "budget {budget_pct}%: cs faults {cs} !< rs faults {rs}");
        assert!(ss < rs, "budget {budget_pct}%: ss faults {ss} !< rs faults {rs}");
    }
}

/// Tentpole acceptance: solver trajectories are **bit-identical** with
/// readahead {off, on} × budgets {1 page, 25%, 100%} × {CS, SS, RS}, on
/// both the synchronous and the pipelined driver paths — readahead only
/// moves disk time off the critical path, never changes a byte.
#[test]
fn trajectories_bit_identical_with_readahead_on_and_off() {
    let page_bytes = 2048u64;
    let ds = dense_ds(2400, 6, 17);
    for sampling in [SamplingKind::Cs, SamplingKind::Ss, SamplingKind::Rs] {
        let incore = run_experiment(&cfg(SolverKind::Saga, sampling, 100), &ds).unwrap();
        for budget in [page_bytes, ds.file_bytes() / 4, ds.file_bytes()] {
            for (depth, readahead) in [(0usize, 0u64), (0, 32), (2, 0), (2, 32)] {
                let (path, paged) = paged_copy(&ds, budget, page_bytes);
                let mut c = cfg(SolverKind::Saga, sampling, 100);
                c.prefetch_depth = depth;
                c.storage.readahead_pages = readahead;
                let ooc = run_experiment(&c, &paged).unwrap();
                let tag = format!(
                    "{} budget={budget} depth={depth} readahead={readahead}",
                    sampling.label()
                );
                assert_eq!(incore.w, ooc.w, "{tag}: iterates");
                assert_eq!(
                    incore.final_objective.to_bits(),
                    ooc.final_objective.to_bits(),
                    "{tag}: objective"
                );
                std::fs::remove_file(path).ok();
            }
        }
    }
}

/// Acceptance: contiguous (CS/SS) epochs through the readahead-enabled
/// pipeline take **zero** demand faults at budgets ≥ 25% — every fault is
/// absorbed by the readahead thread, overlapped with (what would be)
/// compute. Deterministic because the reader waits for each batch's
/// prefault and the window is clamped far below the pool capacity, so a
/// prefetched page can never be evicted before its batch is assembled
/// (window 32 + ~5 pages/batch ≪ 100-page budget).
#[test]
fn readahead_zeroes_demand_faults_for_contiguous_access_at_quarter_budget() {
    let ds = dense_ds(50_000, 8, 5);
    for budget_pct in [25u64, 100] {
        for kind in [SamplingKind::Cs, SamplingKind::Ss] {
            let budget = ds.file_bytes() * budget_pct / 100;
            let (path, paged) = paged_copy(&ds, budget, 4096);
            let arc: Arc<Dataset> = Arc::new(paged.clone());
            let sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &arc, 1 << 20);
            let mut pf = Prefetcher::spawn_with_readahead(arc.clone(), sim, 2, 32);
            let sampler: Box<dyn Sampler> = kind.build(50_000, 500, 7, None).unwrap();
            for e in 0..2 {
                pf.start_epoch(sampler.schedule(e));
                while let Some(b) = pf.next_batch().unwrap() {
                    std::hint::black_box(b.rows);
                }
            }
            pf.finish();
            let io = paged.io_stats();
            assert_eq!(
                io.demand_faults, 0,
                "{} at {budget_pct}%: demand faults must be zero ({io:?})",
                kind.label()
            );
            assert!(io.page_faults > 0, "the readahead thread did the faulting");
            assert!(io.readahead_hits > 0);
            std::fs::remove_file(path).ok();
        }
    }
}

/// Satellite: the deterministic atomic-counter pattern (same as the
/// prefetch backpressure-stall test) — publish a whole CS epoch to the
/// readahead thread, observe its live `completed_batches` counter until
/// every batch is prefaulted (no sleeps), then assemble on the demand
/// path and prove demand faults stayed at zero at a 100% budget.
#[test]
fn readahead_counter_proves_zero_demand_faults_for_cs_at_full_budget() {
    let ds = dense_ds(20_000, 8, 13);
    let (path, paged) = paged_copy(&ds, ds.file_bytes(), 4096);
    let p = paged.as_paged().unwrap();
    // raw handle with an effectively unbounded window: nothing paces the
    // thread, so `completed` provably reaches the published count
    let mut ra = Readahead::spawn(p.store().clone(), u64::MAX / 2);
    let sampler: Box<dyn Sampler> = SamplingKind::Cs.build(20_000, 500, 7, None).unwrap();
    let sels = sampler.schedule(0);
    let total = sels.len() as u64;
    for sel in &sels {
        ra.publish(p.selection_runs(sel));
    }
    while ra.completed_batches() < total {
        std::thread::yield_now();
    }
    assert!(ra.failed().is_none());
    let mut asm = BatchAssembler::new();
    for sel in &sels {
        std::hint::black_box(asm.assemble(&paged, sel).unwrap().rows());
    }
    let io = paged.io_stats();
    assert_eq!(io.demand_faults, 0, "all faults happened on the readahead thread");
    assert_eq!(io.page_faults, p.n_pages(), "one readahead fault per page");
    assert!(io.readahead_hits > 0, "demand touches were served by prefetched pages");
    assert!(io.stall_s <= io.read_s, "stall is the demand-visible slice of read time");
    drop(ra);
    std::fs::remove_file(path).ok();
}

/// De-panicking acceptance: a file that turns unreadable mid-training
/// fails the run with the store's typed error — through the synchronous
/// driver, the pipelined driver and the data-parallel trainer — instead of
/// aborting the process.
#[test]
fn unreadable_file_fails_run_with_typed_error_not_panic() {
    let ds = dense_ds(4000, 6, 23);
    let (path, paged) = paged_copy(&ds, ds.file_bytes() / 4, 2048);
    // truncate the on-disk file after open: later page runs cannot be read
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 3]).unwrap();
    for depth in [0usize, 2] {
        let mut c = cfg(SolverKind::Mbsgd, SamplingKind::Cs, 100);
        c.prefetch_depth = depth;
        let err = run_experiment(&c, &paged).expect_err("must fail, not abort");
        let msg = err.to_string();
        assert!(msg.contains("corrupt") || msg.contains("io error"), "depth={depth}: {msg}");
    }
    let err = samplex::train::parallel::run_data_parallel(
        &cfg(SolverKind::Mbsgd, SamplingKind::Cs, 100),
        &paged,
        3,
    )
    .expect_err("parallel trainer must fail typed");
    assert!(!err.to_string().is_empty());
    std::fs::remove_file(path).ok();
}

/// The paged path composes with the data-parallel trainer (§5): shards
/// assemble out of the shared store and converge like the in-core run.
#[test]
fn data_parallel_trains_out_of_core() {
    let ds = dense_ds(4000, 6, 9);
    let (path, paged) = paged_copy(&ds, ds.file_bytes() / 4, 4096);
    let c = cfg(SolverKind::Mbsgd, SamplingKind::Cs, 100);
    let par_incore = samplex::train::parallel::run_data_parallel(&c, &ds, 3).unwrap();
    let par_paged = samplex::train::parallel::run_data_parallel(&c, &paged, 3).unwrap();
    assert_eq!(par_incore.w, par_paged.w, "parallel shards must match bit for bit");
    assert!(paged.io_stats().bytes_read > 0);
    std::fs::remove_file(path).ok();
}
