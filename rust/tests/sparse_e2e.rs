//! End-to-end CSR data plane: the paper's high-dimensional regime.
//!
//! A ≥100k-column, ≤1%-density synthetic — impossible to densify at any
//! interesting row count — must load in O(nnz) and train through **all five
//! solvers** under every sampling technique:
//!
//! * CS/SS stream zero-copy: no feature or index bytes copied, pinned both
//!   by the pipeline byte counters and by pointer equality against the
//!   dataset's own arrays;
//! * RS pays a counted gather of values *and* index bytes;
//! * the storage simulator charges nnz-proportional bytes, orders of
//!   magnitude below the `rows * cols * 4` a dense layout would cost.

use std::sync::Arc;

use samplex::config::ExperimentConfig;
use samplex::data::batch::RowSelection;
use samplex::data::synth::{generate_csr, SparseSynthSpec};
use samplex::data::Dataset;
use samplex::pipeline::prefetch::Prefetcher;
use samplex::sampling::SamplingKind;
use samplex::solvers::SolverKind;
use samplex::storage::profile::DeviceProfile;
use samplex::storage::simulator::AccessSimulator;

const ROWS: usize = 600;
const COLS: usize = 120_000;
const NNZ_PER_ROW: usize = 40; // density ~0.033%, well under 1%

fn highdim() -> Dataset {
    generate_csr(
        &SparseSynthSpec {
            name: "highdim",
            rows: ROWS,
            cols: COLS,
            nnz_per_row: NNZ_PER_ROW,
            flip_prob: 0.02,
            margin_noise: 0.2,
            pos_fraction: 0.5,
        },
        42,
    )
    .unwrap()
    .into()
}

fn cfg(solver: SolverKind, sampling: SamplingKind) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("highdim", solver, sampling, 100);
    c.epochs = 3;
    c.reg_c = Some(1e-3);
    c.storage.profile = "hdd".into();
    c.storage.cache_mib = 0;
    c.prefetch_depth = 2;
    c
}

#[test]
fn highdim_loads_in_nnz_space() {
    let ds = highdim();
    assert!(ds.cols() >= 100_000);
    let density = ds.nnz() as f64 / (ds.rows() * ds.cols()) as f64;
    assert!(density <= 0.01, "density {density}");
    // storage is O(nnz): the on-disk encoding must be millions of times
    // smaller than the dense image
    let dense_bytes = (ds.rows() * ds.cols()) as u64 * 4;
    assert!(ds.file_bytes() < dense_bytes / 500, "{} vs {dense_bytes}", ds.file_bytes());
}

#[test]
fn all_five_solvers_train_zero_copy_under_cs_and_ss() {
    let ds = highdim();
    for solver in SolverKind::all() {
        for sampling in [SamplingKind::Cs, SamplingKind::Ss] {
            let r = samplex::train::run_experiment(&cfg(solver, sampling), &ds).unwrap();
            assert_eq!(
                r.time.bytes_copied,
                0,
                "{}/{}: contiguous CSR batches must be zero-copy",
                solver.label(),
                sampling.label()
            );
            assert!(r.time.bytes_borrowed > 0);
            assert_eq!(r.time.copy_fraction(), 0.0);
            let first = r.trace.points.first().unwrap().objective;
            assert!(
                r.final_objective < first,
                "{}/{}: {} !< {first}",
                solver.label(),
                sampling.label(),
                r.final_objective
            );
            assert!(r.w.iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn all_five_solvers_pay_counted_gather_under_rs() {
    let ds = highdim();
    for solver in SolverKind::all() {
        let r = samplex::train::run_experiment(&cfg(solver, SamplingKind::Rs), &ds).unwrap();
        assert!(
            r.time.bytes_copied > 0,
            "{}: RS gathers must be counted",
            solver.label()
        );
        // every row is visited once per epoch: the copied bytes are exactly
        // epochs * (values + indices) of the whole matrix
        assert_eq!(r.time.bytes_copied, 3 * ds.nnz() as u64 * 8);
        if solver == SolverKind::Svrg {
            // SVRG's per-epoch full-gradient sweep is contiguous and
            // streams zero-copy even in the RS arm
            assert_eq!(r.time.bytes_borrowed, 3 * ds.nnz() as u64 * 8);
        } else {
            assert_eq!(r.time.bytes_borrowed, 0);
            assert_eq!(r.time.copy_fraction(), 1.0);
        }
    }
}

#[test]
fn cs_batches_alias_the_dataset_arrays_at_high_dim() {
    let ds = Arc::new(highdim());
    let c = ds.as_csr().unwrap();
    let (vals, idx, ptr) = c.arrays();
    let sim = AccessSimulator::for_dataset(DeviceProfile::ssd(), &ds, 0);
    let mut pf = Prefetcher::spawn(ds.clone(), sim, 2);
    let sels: Vec<RowSelection> = (0..6)
        .map(|j| RowSelection::Contiguous { start: j * 100, end: (j + 1) * 100 })
        .collect();
    pf.start_epoch(sels);
    let mut seen = 0;
    while let Some(b) = pf.next_batch().unwrap() {
        let view = b.view(COLS);
        let v = view.as_csr().unwrap();
        let lo = ptr[seen * 100] as usize;
        assert_eq!(v.values.as_ptr(), vals[lo..].as_ptr(), "values must alias");
        assert_eq!(v.col_idx.as_ptr(), idx[lo..].as_ptr(), "indices must alias");
        assert_eq!(v.row_ptr.as_ptr(), ptr[seen * 100..].as_ptr(), "row_ptr must alias");
        seen += 1;
    }
    assert_eq!(seen, 6);
    let es = pf.last_epoch_stats();
    assert_eq!(es.bytes_copied, 0);
    assert_eq!(es.bytes_borrowed, c.nnz() as u64 * 8);
    pf.finish();
}

#[test]
fn simulated_access_is_nnz_proportional_at_high_dim() {
    let ds = highdim();
    let mut sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &ds, 0);
    let cost = sim.fetch(&RowSelection::Contiguous { start: 0, end: ROWS });
    // the dense image would be ROWS * COLS * 4 ≈ 288 MB; the CSR sweep is
    // bounded by nnz * 8 plus one block of slop
    let nnz_bytes = ds.nnz() as u64 * 8;
    assert!(cost.bytes_transferred <= nnz_bytes + 2 * 4096, "{}", cost.bytes_transferred);
    assert!(cost.bytes_transferred >= nnz_bytes / 2);
    let dense_bytes = (ROWS * COLS) as u64 * 4;
    assert!(cost.bytes_transferred < dense_bytes / 100);
}

#[test]
fn sparse_cs_access_time_beats_rs() {
    // the paper's headline ordering must hold on the sparse plane too
    let ds = highdim();
    let t = |s: SamplingKind| {
        let r = samplex::train::run_experiment(&cfg(SolverKind::Mbsgd, s), &ds).unwrap();
        r.time.sim_access_s
    };
    let (rs, cs, ss) = (t(SamplingKind::Rs), t(SamplingKind::Cs), t(SamplingKind::Ss));
    assert!(cs < rs / 2.0, "cs={cs} rs={rs}");
    assert!(ss < rs / 2.0, "ss={ss} rs={rs}");
}

#[test]
fn prefetched_and_sync_paths_agree_on_csr() {
    let ds = highdim();
    let mut sync_cfg = cfg(SolverKind::Saga, SamplingKind::Ss);
    sync_cfg.prefetch_depth = 0;
    let mut pf_cfg = sync_cfg.clone();
    pf_cfg.prefetch_depth = 3;
    let a = samplex::train::run_experiment(&sync_cfg, &ds).unwrap();
    let b = samplex::train::run_experiment(&pf_cfg, &ds).unwrap();
    assert_eq!(a.w, b.w, "identical selections + math ⇒ identical iterates");
    assert!((a.time.sim_access_s - b.time.sim_access_s).abs() < 1e-12);
    assert_eq!(a.time.bytes_borrowed, b.time.bytes_borrowed);
}
