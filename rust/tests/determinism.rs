//! The compute-plane determinism contract: every pooled reduction is
//! **bit-identical for every pool parallelism** (the fixed chunk geometry
//! + slot-isolated partials + serial fixed-order fold rule of
//! `math::chunked`).
//!
//! These tests sweep parallelism {1, 2, 8} over the full objective, the
//! full gradient and `estimate_optimum`, on dense and CSR layouts, and
//! pin the pooled gradient against the serial reference fold exactly.
//! Because the contract holds for *any* setting, the tests stay valid
//! even if another test mutates the global parallelism knob concurrently.
//!
//! The same contract extends across the *kernel dispatch* axis: the
//! portable scalar table and the best detected SIMD table (AVX2/NEON)
//! must produce bit-identical objectives, gradients, and whole solver
//! trajectories — pinned by the `scalar_and_simd_*` tests below, which
//! serialize on a local mutex because the dispatch override is
//! process-global.

use std::sync::Mutex;

use samplex::backend::{ComputeBackend, NativeBackend};
use samplex::config::ExperimentConfig;
use samplex::data::csr::CsrDataset;
use samplex::data::dense::DenseDataset;
use samplex::data::Dataset;
use samplex::math::chunked::{self, GradScratch};
use samplex::math::simd;
use samplex::rng::Rng;
use samplex::runtime::pool;
use samplex::sampling::SamplingKind;
use samplex::solvers::SolverKind;
use samplex::train::estimate_optimum;

const POOL_SIZES: [usize; 3] = [1, 2, 8];

fn dense_ds(rows: usize, cols: usize, seed: u64) -> (Dataset, Vec<f32>) {
    let mut rng = Rng::seed_from(seed);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..rows)
        .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
        .collect();
    let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.4).collect();
    (DenseDataset::new("det-dense", cols, x, y).unwrap().into(), w)
}

fn csr_ds(rows: usize, cols: usize, density: f64, seed: u64) -> (Dataset, Vec<f32>) {
    let mut rng = Rng::seed_from(seed);
    let mut values = Vec::new();
    let mut col_idx = Vec::new();
    let mut row_ptr = vec![0u64];
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        for j in 0..cols {
            if rng.uniform() < density {
                values.push(rng.normal() as f32);
                col_idx.push(j as u32);
            }
        }
        row_ptr.push(values.len() as u64);
        y.push(if rng.uniform() < 0.5 { 1.0 } else { -1.0 });
    }
    let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.4).collect();
    (
        Dataset::Csr(CsrDataset::new("det-csr", cols, values, col_idx, row_ptr, y).unwrap()),
        w,
    )
}

/// Run `f` once per pool size and assert all results are bit-identical.
fn across_pool_sizes<T: PartialEq + std::fmt::Debug>(label: &str, mut f: impl FnMut() -> T) {
    let mut results: Vec<(usize, T)> = Vec::new();
    for threads in POOL_SIZES {
        pool::set_parallelism(threads);
        let got = f();
        pool::set_parallelism(0);
        results.push((threads, got));
    }
    let (t0, want) = &results[0];
    for (t, got) in &results[1..] {
        assert_eq!(got, want, "{label}: pool={t} differs from pool={t0}");
    }
}

#[test]
fn full_objective_bit_identical_across_pool_sizes_dense_and_csr() {
    // > 2 chunks of 4096 rows so the fold is genuinely multi-chunk
    let (dense, wd) = dense_ds(10_000, 12, 0xD0);
    let (csr, ws) = csr_ds(9_000, 40, 0.1, 0xD1);
    let mut be = NativeBackend::new();
    across_pool_sizes("objective/dense", || {
        be.full_objective(&wd, &dense, 1e-3).unwrap().to_bits()
    });
    let mut be = NativeBackend::new();
    across_pool_sizes("objective/csr", || {
        be.full_objective(&ws, &csr, 1e-3).unwrap().to_bits()
    });
}

#[test]
fn full_gradient_bit_identical_across_pool_sizes_dense_and_csr() {
    let (dense, wd) = dense_ds(10_000, 12, 0xE0);
    let (csr, ws) = csr_ds(9_000, 40, 0.1, 0xE1);
    for (label, ds, w) in [("grad/dense", &dense, &wd), ("grad/csr", &csr, &ws)] {
        let cols = ds.cols();
        across_pool_sizes(label, || {
            let mut g = vec![0f32; cols];
            let mut scratch = GradScratch::default();
            chunked::full_grad_into(w, ds, 1e-3, &mut g, &mut scratch).unwrap();
            g.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        });
    }
}

#[test]
fn estimate_optimum_bit_identical_across_pool_sizes() {
    let (dense, _) = dense_ds(6_000, 8, 0xF0);
    let (csr, _) = csr_ds(5_000, 20, 0.15, 0xF1);
    for (label, ds) in [("p*/dense", &dense), ("p*/csr", &csr)] {
        across_pool_sizes(label, || {
            let mut be = NativeBackend::new();
            estimate_optimum(&mut be, ds, 1e-3, 40).unwrap().to_bits()
        });
    }
}

#[test]
fn prop_pooled_grad_matches_serial_kernel_exactly() {
    // property sweep: for random shapes/chunk sizes, the pooled fold must
    // equal the serial chunk fold bit-for-bit (dense and CSR)
    for case in 0u64..12 {
        let mut rng = Rng::seed_from(0x9009 + case * 7919);
        let rows = 50 + rng.below(3000);
        let cols = 2 + rng.below(24);
        let chunk = 1 + rng.below(rows);
        let (ds, w) = if case % 2 == 0 {
            dense_ds(rows, cols, 0x77 + case)
        } else {
            csr_ds(rows, cols, 0.2, 0x77 + case)
        };
        let c = if case % 3 == 0 { 0.0 } else { 0.05 };

        // serial reference: same geometry, same fold order, serial kernels
        let mut want = vec![0f32; cols];
        let mut g = vec![0f32; cols];
        let mut start = 0;
        while start < rows {
            let end = (start + chunk).min(rows);
            samplex::math::grad_into_view(&w, &ds.slice_view(start, end), 0.0, &mut g);
            samplex::math::axpy((end - start) as f32 / rows as f32, &g, &mut want);
            start = end;
        }
        samplex::math::axpy(c, &w, &mut want);

        let mut got = vec![0f32; cols];
        let mut scratch = GradScratch::default();
        chunked::full_grad_into_chunked(&w, &ds, c, chunk, &mut got, &mut scratch).unwrap();
        assert_eq!(
            got, want,
            "case {case}: rows={rows} cols={cols} chunk={chunk} c={c}"
        );
    }
}

/// Serializes the tests that flip the process-global kernel dispatch.
static DISPATCH: Mutex<()> = Mutex::new(());

/// Run `f` under the forced-scalar table and the best available table and
/// assert bit-identical results (the SIMD overhaul's core contract).
fn scalar_vs_best<T: PartialEq + std::fmt::Debug>(label: &str, mut f: impl FnMut() -> T) {
    simd::force_scalar();
    let scalar = f();
    simd::force_best();
    let best = f();
    assert_eq!(
        scalar,
        best,
        "{label}: scalar vs `{}` kernels must be bit-identical",
        simd::active_name()
    );
}

#[test]
fn scalar_and_simd_bit_identical_objective_and_gradient() {
    let _g = DISPATCH.lock().unwrap();
    // 33 columns: a 4-wide f64 main body plus a 1-element tail for the
    // loss path, and an 8-wide f32 body plus tail for the gradient path
    let (dense, wd) = dense_ds(6_000, 33, 0xA0);
    let (csr, ws) = csr_ds(4_000, 40, 0.12, 0xA1);
    for pool_threads in [1, 8] {
        pool::set_parallelism(pool_threads);
        for (label, ds, w) in [("dense", &dense, &wd), ("csr", &csr, &ws)] {
            let cols = ds.cols();
            scalar_vs_best(&format!("objective/{label}/pool={pool_threads}"), || {
                let mut be = NativeBackend::new();
                be.full_objective(w, ds, 1e-3).unwrap().to_bits()
            });
            scalar_vs_best(&format!("gradient/{label}/pool={pool_threads}"), || {
                let mut g = vec![0f32; cols];
                let mut scratch = GradScratch::default();
                chunked::full_grad_into(w, ds, 1e-3, &mut g, &mut scratch).unwrap();
                g.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            });
        }
    }
    pool::set_parallelism(0);
}

#[test]
fn scalar_and_simd_bit_identical_solver_trajectories() {
    let _g = DISPATCH.lock().unwrap();
    let (dense, _) = dense_ds(1_200, 10, 0xB0);
    let (csr, _) = csr_ds(1_000, 30, 0.15, 0xB1);
    // every solver on the dense row-major kernels
    for kind in [
        SolverKind::Mbsgd,
        SolverKind::Sag,
        SolverKind::Saga,
        SolverKind::Svrg,
        SolverKind::Saag2,
    ] {
        let mut cfg = ExperimentConfig::quick("simd-parity", kind, SamplingKind::Cs, 100);
        cfg.epochs = 3;
        cfg.reg_c = Some(1e-3);
        scalar_vs_best(&format!("trajectory/{kind:?}/dense"), || {
            let r = samplex::train::run_experiment(&cfg, &dense).unwrap();
            r.w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        });
    }
    // SAGA additionally on CSR: the gather-based sparse_dot kernel plus
    // the lazy-scaling scatter path
    let mut cfg =
        ExperimentConfig::quick("simd-parity-csr", SolverKind::Saga, SamplingKind::Cs, 100);
    cfg.epochs = 3;
    cfg.reg_c = Some(1e-3);
    scalar_vs_best("trajectory/Saga/csr", || {
        let r = samplex::train::run_experiment(&cfg, &csr).unwrap();
        r.w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
    });
}

#[test]
fn traced_and_untraced_trajectories_bit_identical_all_solvers() {
    // the observability plane's core promise: arming the tracer records
    // spans but never perturbs a trajectory — every solver's weights are
    // bit-identical with tracing on and off
    let (dense, _) = dense_ds(1_200, 10, 0xC0);
    for kind in [
        SolverKind::Mbsgd,
        SolverKind::Sag,
        SolverKind::Saga,
        SolverKind::Svrg,
        SolverKind::Saag2,
    ] {
        let mut cfg = ExperimentConfig::quick("trace-parity", kind, SamplingKind::Cs, 100);
        cfg.epochs = 3;
        cfg.reg_c = Some(1e-3);
        samplex::obs::disarm();
        let plain = samplex::train::run_experiment(&cfg, &dense).unwrap();
        samplex::obs::arm();
        let traced = samplex::train::run_experiment(&cfg, &dense).unwrap();
        samplex::obs::disarm();
        assert_eq!(
            plain.w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
            traced.w.iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
            "{kind:?}: traced vs untraced weights must be bit-identical"
        );
        assert_eq!(
            plain.trace.final_objective().map(f64::to_bits),
            traced.trace.final_objective().map(f64::to_bits),
            "{kind:?}: traced vs untraced objectives must be bit-identical"
        );
        // untraced runs attribute nothing; traced runs attribute something
        assert_eq!(plain.attr, samplex::obs::Attribution::default(), "{kind:?}");
        assert!(traced.attr.union_s() >= 0.0, "{kind:?}");
    }
}

#[test]
fn pooled_objective_matches_trait_default_serial_sweep() {
    // the native override must reproduce the serial default trait method
    // (same 4096-row chunking, same fold order) bit-for-bit — pinned here
    // via a minimal serial backend that only forwards loss_sum
    struct SerialOracle(NativeBackend);
    impl ComputeBackend for SerialOracle {
        fn name(&self) -> &'static str {
            "serial-oracle"
        }
        fn grad_into(
            &mut self,
            w: &[f32],
            b: &samplex::data::batch::BatchView<'_>,
            c: f32,
            out: &mut [f32],
        ) -> samplex::Result<()> {
            self.0.grad_into(w, b, c, out)
        }
        fn batch_obj(
            &mut self,
            w: &[f32],
            b: &samplex::data::batch::BatchView<'_>,
            c: f32,
        ) -> samplex::Result<f64> {
            self.0.batch_obj(w, b, c)
        }
        fn loss_sum(
            &mut self,
            w: &[f32],
            b: &samplex::data::batch::BatchView<'_>,
        ) -> samplex::Result<f64> {
            self.0.loss_sum(w, b)
        }
        // no full_objective override: uses the serial default
    }

    let (dense, wd) = dense_ds(10_000, 10, 0xAB);
    let mut serial = SerialOracle(NativeBackend::new());
    let mut pooled = NativeBackend::new();
    let a = serial.full_objective(&wd, &dense, 0.01).unwrap();
    let b = pooled.full_objective(&wd, &dense, 0.01).unwrap();
    assert_eq!(a.to_bits(), b.to_bits(), "pooled override must match serial default");
}
