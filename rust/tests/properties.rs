//! Property-based tests over randomized inputs (in-tree harness: the build
//! is offline, so instead of proptest we sweep seeded random cases — same
//! invariants, deterministic shrink-free reporting of the failing seed).

use std::sync::Arc;

use samplex::backend::{ComputeBackend, NativeBackend};
use samplex::data::batch::{gather_owned, BatchView, RowSelection};
use samplex::data::csr::CsrDataset;
use samplex::data::dense::DenseDataset;
use samplex::data::Dataset;
use samplex::pipeline::prefetch::Prefetcher;
use samplex::rng::Rng;
use samplex::sampling::{Sampler, SamplingKind};
use samplex::solvers::{Solver, SolverKind};
use samplex::storage::blockmap::BlockMap;
use samplex::storage::profile::DeviceProfile;
use samplex::storage::simulator::AccessSimulator;

/// Deterministic case sweep helper: calls `f(case_rng, case_idx)`.
fn sweep(cases: usize, seed: u64, mut f: impl FnMut(&mut Rng, usize)) {
    for i in 0..cases {
        let mut rng = Rng::seed_from(seed.wrapping_add(i as u64 * 7919));
        f(&mut rng, i);
    }
}

fn random_dims(rng: &mut Rng) -> (usize, usize) {
    let rows = 2 + rng.below(600);
    let batch = 1 + rng.below(rows);
    (rows, batch)
}

// ---------------------------------------------------------------------------
// Sampler invariants (the paper's §2.1 definitions)
// ---------------------------------------------------------------------------

#[test]
fn prop_partition_samplers_cover_each_row_exactly_once() {
    // RS-without, CS, SS and STRAT partition the dataset every epoch
    sweep(60, 0xA11CE, |rng, i| {
        let (rows, batch) = random_dims(rng);
        let labels: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.4 { 1.0 } else { -1.0 })
            .collect();
        for kind in [SamplingKind::Rs, SamplingKind::Cs, SamplingKind::Ss, SamplingKind::Stratified]
        {
            let mut s = kind.build(rows, batch, i as u64, Some(&labels)).unwrap();
            for epoch in [0usize, 3] {
                let mut seen = vec![0u32; rows];
                for sel in s.epoch(epoch) {
                    for r in sel.iter() {
                        seen[r] += 1;
                    }
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "case {i}: {} rows={rows} batch={batch} epoch={epoch}",
                    kind.label()
                );
            }
        }
    });
}

#[test]
fn prop_batch_sizes_match_paper_partition_rule() {
    // all batches equal `batch` except a ragged last one (§4.2)
    sweep(60, 0xB0B, |rng, i| {
        let (rows, batch) = random_dims(rng);
        for kind in [SamplingKind::Rs, SamplingKind::Rswr, SamplingKind::Cs, SamplingKind::Ss] {
            let mut s = kind.build(rows, batch, i as u64, None).unwrap();
            let mut sizes: Vec<usize> = s.epoch(1).iter().map(|b| b.len()).collect();
            let m = rows.div_ceil(batch);
            assert_eq!(sizes.len(), m, "{}", kind.label());
            // SS visits the partition in shuffled order, so the ragged
            // batch may appear anywhere — compare as a multiset
            sizes.sort_unstable();
            let mut want = vec![batch; m];
            if rows % batch != 0 {
                want[0] = rows % batch;
                want[1..].fill(batch);
            }
            want.sort_unstable();
            assert_eq!(sizes, want, "{} case {i}", kind.label());
        }
    });
}

#[test]
fn prop_cs_ss_batches_always_contiguous_rs_scattered() {
    sweep(40, 0xC5, |rng, i| {
        let (rows, batch) = random_dims(rng);
        let mut cs = SamplingKind::Cs.build(rows, batch, i as u64, None).unwrap();
        let mut ss = SamplingKind::Ss.build(rows, batch, i as u64, None).unwrap();
        let mut rs = SamplingKind::Rs.build(rows, batch, i as u64, None).unwrap();
        assert!(cs.epoch(i).iter().all(|b| b.is_contiguous()));
        assert!(ss.epoch(i).iter().all(|b| b.is_contiguous()));
        assert!(rs.epoch(i).iter().all(|b| !b.is_contiguous()));
    });
}

#[test]
fn prop_ss_is_permutation_of_cs_batches() {
    // SS = CS partition in randomized order (the paper's definition)
    sweep(40, 0x55, |rng, i| {
        let (rows, batch) = random_dims(rng);
        let mut cs = SamplingKind::Cs.build(rows, batch, 1, None).unwrap();
        let mut ss = SamplingKind::Ss.build(rows, batch, i as u64, None).unwrap();
        let norm = |v: Vec<RowSelection>| {
            let mut k: Vec<(usize, usize)> = v
                .iter()
                .map(|s| match s {
                    RowSelection::Contiguous { start, end } => (*start, *end),
                    _ => panic!("not contiguous"),
                })
                .collect();
            k.sort_unstable();
            k
        };
        assert_eq!(norm(cs.epoch(i)), norm(ss.epoch(i)), "case {i}");
        let _ = rng;
    });
}

#[test]
fn prop_samplers_deterministic_in_seed() {
    sweep(20, 0xD371, |rng, i| {
        let (rows, batch) = random_dims(rng);
        for kind in [SamplingKind::Rs, SamplingKind::Rswr, SamplingKind::Ss] {
            let mut a = kind.build(rows, batch, 99, None).unwrap();
            let mut b = kind.build(rows, batch, 99, None).unwrap();
            assert_eq!(a.epoch(i), b.epoch(i), "{} case {i}", kind.label());
        }
    });
}

// ---------------------------------------------------------------------------
// Storage model invariants (the paper's §1/§2.1 access-cost reasoning)
// ---------------------------------------------------------------------------

fn sim_for(rows: usize, cols: usize, profile: DeviceProfile, cache_blocks: usize) -> AccessSimulator {
    let map = BlockMap::uniform(24 + rows as u64 * 4, cols as u64 * 4, profile.block_bytes);
    AccessSimulator::new(profile, map, cache_blocks)
}

#[test]
fn prop_access_cost_ordering_cs_le_ss_le_rs() {
    // Theorem-level invariant of the model: per epoch,
    // access(CS) <= access(SS) (equal partitions, order irrelevant w/o cache)
    // and both << access(RS) when rows are block-dispersed
    sweep(30, 0x0FD1234, |rng, i| {
        let rows = 200 + rng.below(2000);
        let cols = 4 + rng.below(60);
        let batch = 10 + rng.below(rows / 2);
        let mut sims: Vec<AccessSimulator> =
            (0..3).map(|_| sim_for(rows, cols, DeviceProfile::hdd(), 0)).collect();
        let mut totals = Vec::new();
        for (kind, sim) in
            [SamplingKind::Cs, SamplingKind::Ss, SamplingKind::Rs].iter().zip(sims.iter_mut())
        {
            let mut s = kind.build(rows, batch, i as u64, None).unwrap();
            for sel in s.epoch(0) {
                sim.fetch(&sel);
            }
            totals.push(sim.total.time_s);
        }
        let (cs, ss, rs) = (totals[0], totals[1], totals[2]);
        assert!(cs <= ss + 1e-12, "case {i}: cs={cs} ss={ss}");
        assert!(ss < rs, "case {i}: ss={ss} rs={rs}");
    });
}

#[test]
fn prop_rs_transfers_at_least_as_many_bytes() {
    // dispersed access can only touch more blocks than contiguous
    sweep(30, 0xBEEF, |rng, i| {
        let rows = 100 + rng.below(1500);
        let cols = 2 + rng.below(40);
        let batch = 5 + rng.below(rows / 2);
        let mut sim_cs = sim_for(rows, cols, DeviceProfile::ssd(), 0);
        let mut sim_rs = sim_for(rows, cols, DeviceProfile::ssd(), 0);
        let mut cs = SamplingKind::Cs.build(rows, batch, i as u64, None).unwrap();
        let mut rs = SamplingKind::Rs.build(rows, batch, i as u64, None).unwrap();
        for sel in cs.epoch(0) {
            sim_cs.fetch(&sel);
        }
        for sel in rs.epoch(0) {
            sim_rs.fetch(&sel);
        }
        assert!(
            sim_rs.total.bytes_transferred >= sim_cs.total.bytes_transferred,
            "case {i}"
        );
    });
}

#[test]
fn prop_cache_never_increases_cost() {
    sweep(20, 0xCACE, |rng, i| {
        let rows = 100 + rng.below(800);
        let cols = 4 + rng.below(30);
        let batch = 5 + rng.below(rows / 2);
        for kind in [SamplingKind::Cs, SamplingKind::Rs] {
            let mut cold = sim_for(rows, cols, DeviceProfile::hdd(), 0);
            let mut warm = sim_for(rows, cols, DeviceProfile::hdd(), 1 << 16);
            let mut s1 = kind.build(rows, batch, i as u64, None).unwrap();
            let mut s2 = kind.build(rows, batch, i as u64, None).unwrap();
            for e in 0..3 {
                for sel in s1.epoch(e) {
                    cold.fetch(&sel);
                }
                for sel in s2.epoch(e) {
                    warm.fetch(&sel);
                }
            }
            assert!(
                warm.total.time_s <= cold.total.time_s + 1e-12,
                "{} case {i}: warm={} cold={}",
                kind.label(),
                warm.total.time_s,
                cold.total.time_s
            );
        }
    });
}

#[test]
fn prop_seeks_bounded_by_rows_plus_one() {
    // a batch of b rows can never need more than b positioning events
    // (one per row) plus block-split slop
    sweep(25, 0x5EEC, |rng, i| {
        let rows = 50 + rng.below(500);
        let cols = 2 + rng.below(50);
        let batch = 1 + rng.below(rows);
        let mut sim = sim_for(rows, cols, DeviceProfile::hdd(), 0);
        let mut rs = SamplingKind::Rs.build(rows, batch, i as u64, None).unwrap();
        for sel in rs.epoch(0) {
            let cost = sim.fetch(&sel);
            assert!(
                cost.seeks <= sel.len() as u64 + 1,
                "case {i}: {} seeks for {} rows",
                cost.seeks,
                sel.len()
            );
        }
    });
}

// ---------------------------------------------------------------------------
// Zero-copy pipeline invariants (the Borrowed/Owned payload contract)
// ---------------------------------------------------------------------------

fn random_dataset(rng: &mut Rng, rows: usize, cols: usize) -> DenseDataset {
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..rows)
        .map(|_| if rng.uniform() < 0.5 { 1.0 } else { -1.0 })
        .collect();
    DenseDataset::new("prop", cols, x, y).unwrap()
}

/// Random CSR dataset with ~`density` fill (some rows may be empty).
fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> CsrDataset {
    let mut values = Vec::new();
    let mut col_idx = Vec::new();
    let mut row_ptr = vec![0u64];
    let mut y = Vec::with_capacity(rows);
    for _ in 0..rows {
        for j in 0..cols {
            if rng.uniform() < density {
                values.push(rng.normal() as f32);
                col_idx.push(j as u32);
            }
        }
        row_ptr.push(values.len() as u64);
        y.push(if rng.uniform() < 0.5 { 1.0 } else { -1.0 });
    }
    CsrDataset::new("prop-csr", cols, values, col_idx, row_ptr, y).unwrap()
}

const ALL_KINDS: [SamplingKind; 5] = [
    SamplingKind::Rs,
    SamplingKind::Rswr,
    SamplingKind::Cs,
    SamplingKind::Ss,
    SamplingKind::Stratified,
];

#[test]
fn prop_borrowed_and_forced_owned_payloads_bit_identical() {
    // for every sampler kind: the zero-copy payload the pipeline delivers
    // and a forced owned gather of the same selection hold bit-identical
    // batch contents; contiguous selections really borrow (pointer-equal)
    // and report zero copied bytes
    sweep(10, 0x0B0E, |rng, i| {
        let rows = 20 + rng.below(300);
        let cols = 1 + rng.below(12);
        let batch = 1 + rng.below(rows);
        let dense = random_dataset(rng, rows, cols);
        let ds = Arc::new(Dataset::Dense(dense));
        let labels = ds.y().to_vec();
        for kind in ALL_KINDS {
            let mut s: Box<dyn Sampler> = kind.build(rows, batch, i as u64, Some(&labels)).unwrap();
            let sels = s.epoch(i);
            let sim = AccessSimulator::for_dataset(DeviceProfile::ssd(), &ds, 0);
            let mut pf = Prefetcher::spawn(ds.clone(), sim, 2);
            pf.start_epoch(sels.clone());
            let mut k = 0usize;
            while let Some(b) = pf.next_batch().unwrap() {
                let pview = b.view(cols);
                let view = pview.as_dense().unwrap();
                let owned = gather_owned(&ds, &sels[k]).unwrap();
                let oview = owned.view(cols);
                let od = oview.as_dense().unwrap();
                assert_eq!(view.x, od.x, "{} case {i} batch {k}: x", kind.label());
                assert_eq!(view.y, od.y, "{} case {i} batch {k}: y", kind.label());
                assert_eq!(
                    b.payload.is_borrowed(),
                    sels[k].is_contiguous(),
                    "{} case {i}: payload kind must follow selection kind",
                    kind.label()
                );
                if let RowSelection::Contiguous { start, .. } = sels[k] {
                    assert_eq!(
                        view.x.as_ptr(),
                        ds.as_dense().unwrap().row(start).as_ptr(),
                        "{} case {i}: contiguous view must alias the dataset",
                        kind.label()
                    );
                }
                k += 1;
            }
            assert_eq!(k, sels.len(), "{} case {i}: batch count", kind.label());
            let es = pf.last_epoch_stats();
            if sels.iter().all(|s| s.is_contiguous()) {
                assert_eq!(es.bytes_copied, 0, "{} case {i}", kind.label());
                assert!(es.bytes_borrowed > 0);
            } else {
                assert_eq!(es.bytes_borrowed, 0, "{} case {i}", kind.label());
                assert!(es.bytes_copied > 0);
            }
            pf.finish();
        }
    });
}

#[test]
fn prop_solver_trajectory_identical_on_borrowed_vs_owned_payloads() {
    // one full epoch of SAGA driven by pipeline payloads (zero-copy for
    // CS/SS) must land on a bit-identical iterate to the same epoch driven
    // by forced owned gathers of the same selections
    sweep(5, 0x7AA9, |rng, i| {
        let rows = 60 + rng.below(200);
        let cols = 2 + rng.below(8);
        let batch = 1 + rng.below(rows.min(50));
        let ds = Arc::new(Dataset::Dense(random_dataset(rng, rows, cols)));
        let labels = ds.y().to_vec();
        let lr = 0.05f32;
        for kind in ALL_KINDS {
            let sels = kind
                .build(rows, batch, i as u64, Some(&labels))
                .unwrap()
                .epoch(i);
            let m = sels.len();
            let mut be = NativeBackend::new();

            // run A: payloads through the pipeline
            let mut solver_a: Box<dyn Solver> = SolverKind::Saga.build(cols, m);
            solver_a.set_reg(1e-3);
            let sim = AccessSimulator::for_dataset(DeviceProfile::ram(), &ds, 0);
            let mut pf = Prefetcher::spawn(ds.clone(), sim, 2);
            pf.start_epoch(sels.clone());
            while let Some(b) = pf.next_batch().unwrap() {
                let view = b.view(cols);
                solver_a.step(&mut be, &view, b.j, lr).unwrap();
            }
            pf.finish();

            // run B: forced owned gathers of the same selections
            let mut solver_b: Box<dyn Solver> = SolverKind::Saga.build(cols, m);
            solver_b.set_reg(1e-3);
            for (j, sel) in sels.iter().enumerate() {
                let owned = gather_owned(&ds, sel).unwrap();
                let view = owned.view(cols);
                solver_b.step(&mut be, &view, j, lr).unwrap();
            }

            assert_eq!(
                solver_a.w(),
                solver_b.w(),
                "{} case {i}: trajectories must be bit-identical",
                kind.label()
            );
        }
    });
}


// ---------------------------------------------------------------------------
// Dense ↔ CSR layout equivalence (the Dataset seam contract)
// ---------------------------------------------------------------------------

#[test]
fn prop_dense_and_csr_gradients_bit_close() {
    // random CSR matrices, densified: both kernels must produce the same
    // gradient to within f32 association error (≤ 1e-5)
    sweep(20, 0x0C5A, |rng, i| {
        let rows = 5 + rng.below(120);
        let cols = 3 + rng.below(60);
        let density = 0.05 + rng.uniform() * 0.5;
        let csr = random_csr(rng, rows, cols, density);
        let dense = csr.to_dense().unwrap();
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.5).collect();
        let c = if i % 2 == 0 { 0.0 } else { 0.2 };
        let mut be = NativeBackend::new();
        let mut gd = vec![0f32; cols];
        let mut gs = vec![0f32; cols];
        let dview = BatchView::dense(dense.x(), dense.y(), cols);
        let sview = BatchView::Csr(csr.slice(0, rows));
        be.grad_into(&w, &dview, c, &mut gd).unwrap();
        be.grad_into(&w, &sview, c, &mut gs).unwrap();
        for k in 0..cols {
            assert!(
                (gd[k] - gs[k]).abs() <= 1e-5 * (1.0 + gd[k].abs()),
                "case {i} k={k}: dense {} vs csr {}",
                gd[k],
                gs[k]
            );
        }
        // loss agrees too
        let ld = be.loss_sum(&w, &dview).unwrap();
        let ls = be.loss_sum(&w, &sview).unwrap();
        assert!((ld - ls).abs() <= 1e-4 * (1.0 + ld.abs()), "case {i}: {ld} vs {ls}");
    });
}

#[test]
fn prop_saga_trajectory_identical_dense_vs_csr() {
    // full SAGA epochs driven once through dense views and once through CSR
    // views of the same data must land on the same iterate (≤ 1e-5): the
    // layout seam must not change the optimization path
    sweep(8, 0x5A6A, |rng, i| {
        let rows = 40 + rng.below(150);
        let cols = 4 + rng.below(20);
        let batch = 1 + rng.below(rows.min(40));
        let csr = random_csr(rng, rows, cols, 0.3);
        let dense_ds = Dataset::Dense(csr.to_dense().unwrap());
        let csr_ds = Dataset::Csr(csr);
        let lr = 0.05f32;
        for kind in [SamplingKind::Cs, SamplingKind::Ss, SamplingKind::Rs] {
            let mut be = NativeBackend::new();
            let mut run = |ds: &Dataset| -> Vec<f32> {
                let sels = kind.build(rows, batch, i as u64, None).unwrap().epoch(i);
                let mut solver: Box<dyn Solver> = SolverKind::Saga.build(cols, sels.len());
                solver.set_reg(1e-3);
                let mut asm = samplex::data::batch::BatchAssembler::new();
                for epoch_sels in [&sels, &sels] {
                    for (j, sel) in epoch_sels.iter().enumerate() {
                        let view = asm.assemble(ds, sel).unwrap();
                        solver.step(&mut be, &view, j, lr).unwrap();
                    }
                }
                solver.sync_w();
                solver.w().to_vec()
            };
            let wd = run(&dense_ds);
            let ws = run(&csr_ds);
            for k in 0..cols {
                assert!(
                    (wd[k] - ws[k]).abs() <= 1e-5 * (1.0 + wd[k].abs()),
                    "{} case {i} k={k}: dense {} vs csr {}",
                    kind.label(),
                    wd[k],
                    ws[k]
                );
            }
        }
    });
}

#[test]
fn prop_full_objective_layout_invariant() {
    sweep(12, 0xF0B1, |rng, i| {
        let rows = 10 + rng.below(200);
        let cols = 2 + rng.below(30);
        let csr = random_csr(rng, rows, cols, 0.2);
        let dense_ds = Dataset::Dense(csr.to_dense().unwrap());
        let csr_ds = Dataset::Csr(csr);
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.3).collect();
        let mut be = NativeBackend::new();
        let a = be.full_objective(&w, &dense_ds, 0.01).unwrap();
        let b = be.full_objective(&w, &csr_ds, 0.01).unwrap();
        assert!((a - b).abs() <= 1e-5 * (1.0 + a.abs()), "case {i}: {a} vs {b}");
    });
}

// ---------------------------------------------------------------------------
// Math invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_gradient_descent_direction_decreases_objective() {
    // for small steps, f(w - t g) < f(w): grad_into really is a gradient
    sweep(30, 0x6E4D, |rng, i| {
        let rows = 10 + rng.below(100);
        let cols = 1 + rng.below(20);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32 * 0.5).collect();
        let c = 0.01f32;
        let mut g = vec![0f32; cols];
        samplex::math::grad_into(&w, &x, &y, cols, c, &mut g);
        let gnorm = samplex::math::nrm2_sq(&g);
        if gnorm < 1e-10 {
            return; // stationary — nothing to check
        }
        let f0 = samplex::math::objective_batch(&w, &x, &y, cols, c);
        let t = 1e-3f32 / (1.0 + gnorm as f32);
        let wt: Vec<f32> = w.iter().zip(&g).map(|(wi, gi)| wi - t * gi).collect();
        let ft = samplex::math::objective_batch(&wt, &x, &y, cols, c);
        assert!(ft < f0, "case {i}: {ft} !< {f0}");
    });
}

#[test]
fn prop_objective_strongly_convex_lower_bound() {
    // f(w) >= (C/2)||w - w_reg_opt||^2 sanity: objective with larger C at
    // the same w is larger
    sweep(20, 0xCC, |rng, i| {
        let rows = 10 + rng.below(50);
        let cols = 1 + rng.below(10);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        let f1 = samplex::math::objective_batch(&w, &x, &y, cols, 0.01);
        let f2 = samplex::math::objective_batch(&w, &x, &y, cols, 1.0);
        if samplex::math::nrm2_sq(&w) > 1e-9 {
            assert!(f2 > f1, "case {i}");
        }
    });
}
