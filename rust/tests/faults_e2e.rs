//! Chaos suite: the fault-injected, self-healing data plane must be
//! **invisible** to training. Every test here compares solver iterates
//! bit-for-bit against a fault-free reference:
//!
//! * transient read faults (EINTR / short reads / detected corruption)
//!   are absorbed by the retry + checksum layer — all five solvers
//!   finish with bit-identical `w` and objective;
//! * killing the process at **every** epoch boundary and resuming from
//!   the crash-consistent checkpoint reproduces the uninterrupted
//!   trajectory exactly;
//! * a readahead thread that dies mid-run degrades the experiment to
//!   demand paging (`IoStats::degraded`) without changing a byte;
//! * *persistent* corruption surfaces as the typed [`Error::Corrupt`] —
//!   never a panic, never a silently bad batch.
//!
//! Fault schedules are injected through explicit [`StoreOptions`] (not
//! the `SAMPLEX_FAULTS` env var): tests in one binary run in parallel,
//! and ambient env state would leak between them.
//!
//! The CI chaos job runs exactly this file:
//! `cargo test --release --test faults_e2e`.

use samplex::config::ExperimentConfig;
use samplex::data::synth::{self, FeatureDist, SynthSpec};
use samplex::data::{Dataset, PagedDataset};
use samplex::error::Error;
use samplex::sampling::SamplingKind;
use samplex::solvers::SolverKind;
use samplex::storage::pagestore::StoreOptions;
use samplex::storage::retry::RetryPolicy;
use samplex::testing::faults::FaultSpec;
use samplex::train::run_experiment;

static UNIQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

fn tmp_path(ext: &str) -> std::path::PathBuf {
    let uniq = UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("faults_e2e_{}_{uniq}.{ext}", std::process::id()))
}

fn dense_ds(rows: usize, cols: usize, seed: u64) -> Dataset {
    synth::generate(
        &SynthSpec {
            name: "chaos",
            rows,
            cols,
            dist: FeatureDist::Gaussian,
            flip_prob: 0.05,
            margin_noise: 0.3,
            pos_fraction: 0.5,
        },
        seed,
    )
    .unwrap()
    .into()
}

/// A retry policy generous enough that probabilistic fault schedules
/// cannot exhaust it, with microsecond backoffs so tests don't sleep.
fn generous_retry() -> RetryPolicy {
    RetryPolicy { max_attempts: 30, base_backoff_us: 1, max_backoff_us: 4, op_timeout_ms: 30_000 }
}

/// Save `ds` once and reopen it paged with an injected fault schedule.
/// `page_bytes` stays a multiple of the checksum chunk (1024), so the
/// saved `"SXK1"` footer arms per-chunk verification on every fault.
fn faulty_copy(
    ds: &Dataset,
    budget_bytes: u64,
    spec: Option<FaultSpec>,
    retry: RetryPolicy,
) -> (std::path::PathBuf, Dataset) {
    let p = tmp_path("sxb");
    ds.save(&p).unwrap();
    let opts = StoreOptions { retry, faults: spec, ..StoreOptions::default() };
    let paged: Dataset = PagedDataset::open_with(&p, budget_bytes, 2048, opts).unwrap().into();
    (p, paged)
}

fn cfg(solver: SolverKind, batch: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("chaos", solver, SamplingKind::Ss, batch);
    c.epochs = 2;
    c.reg_c = Some(1e-3);
    c.record_every = 1;
    c
}

/// Tentpole acceptance (transient arm): with EINTR, short reads and
/// detectable bit-flips injected on every code path, all five solvers
/// finish **bit-identical** to the fault-free in-core run — and the
/// store proves it actually recovered something (`IoStats::retries`).
#[test]
fn transient_faults_are_invisible_to_all_five_solvers() {
    let ds = dense_ds(2400, 6, 21);
    let spec = FaultSpec::parse("seed=5,eintr=0.05,short=0.08,corrupt=0.02").unwrap();
    let (path, faulted) =
        faulty_copy(&ds, ds.file_bytes() / 4, Some(spec), generous_retry());
    for solver in SolverKind::all() {
        let mut c = cfg(solver, 100);
        c.prefetch_depth = 2;
        let clean = run_experiment(&c, &ds).unwrap();
        let hurt = run_experiment(&c, &faulted).unwrap();
        assert_eq!(clean.w, hurt.w, "{}: iterates must survive faults", solver.label());
        assert_eq!(
            clean.final_objective.to_bits(),
            hurt.final_objective.to_bits(),
            "{}: objective must survive faults",
            solver.label()
        );
    }
    let io = faulted.io_stats();
    assert!(io.retries > 0, "the schedule should have injected recoverable faults: {io:?}");
    std::fs::remove_file(path).ok();
}

/// Retry accounting is deterministic: two runs with the *same* fault
/// schedule, single-threaded reads (no readahead, synchronous driver,
/// one pool thread) recover the same faults in the same places — equal
/// iterates, equal objectives, equal `IoStats::retries`.
#[test]
fn identically_seeded_fault_runs_recover_identically() {
    let ds = dense_ds(1200, 6, 3);
    let run = || {
        let spec = FaultSpec::parse("seed=11,eintr=0.1,short=0.1").unwrap();
        let (path, faulted) =
            faulty_copy(&ds, ds.file_bytes() / 4, Some(spec), generous_retry());
        let mut c = cfg(SolverKind::Saga, 100);
        c.prefetch_depth = 0;
        c.storage.readahead_pages = 0;
        c.pool_threads = 1;
        let report = run_experiment(&c, &faulted).unwrap();
        let io = faulted.io_stats();
        std::fs::remove_file(path).ok();
        (report.w.clone(), report.final_objective.to_bits(), io.retries)
    };
    let (w_a, obj_a, retries_a) = run();
    let (w_b, obj_b, retries_b) = run();
    assert_eq!(w_a, w_b);
    assert_eq!(obj_a, obj_b);
    assert_eq!(retries_a, retries_b, "retry counts must replay exactly");
    assert!(retries_a > 0, "the schedule should have injected something");
}

/// Tentpole acceptance (crash arm): for every solver, killing the run at
/// **every** epoch boundary and resuming from the checkpoint — on the
/// fault-injected paged plane — lands on exactly the uninterrupted
/// trajectory: same `w` bits, same objective bits, same trace length.
#[test]
fn kill_and_resume_at_every_epoch_boundary_is_bit_identical() {
    let ds = dense_ds(2400, 6, 17);
    let epochs = 4usize;
    for solver in SolverKind::all() {
        let mut full_cfg = cfg(solver, 100);
        full_cfg.epochs = epochs;
        let full = run_experiment(&full_cfg, &ds).unwrap();
        let spec = FaultSpec::parse("seed=7,eintr=0.04,short=0.04").unwrap();
        let (path, faulted) =
            faulty_copy(&ds, ds.file_bytes() / 4, Some(spec), generous_retry());
        for kill_after in 1..epochs {
            let dir = tmp_path(&format!("ckpt_{}_{kill_after}", solver.label()));
            let mut head = full_cfg.clone();
            head.epochs = kill_after;
            head.checkpoint_dir = Some(dir.to_string_lossy().into_owned());
            run_experiment(&head, &faulted).unwrap();
            let mut tail = full_cfg.clone();
            tail.checkpoint_dir = head.checkpoint_dir.clone();
            tail.resume = true;
            let resumed = run_experiment(&tail, &faulted).unwrap();
            let tag = format!("{} killed after epoch {kill_after}", solver.label());
            assert_eq!(full.w, resumed.w, "{tag}: iterates");
            assert_eq!(
                full.final_objective.to_bits(),
                resumed.final_objective.to_bits(),
                "{tag}: objective"
            );
            assert_eq!(
                full.trace.points.len(),
                resumed.trace.points.len(),
                "{tag}: restored trace must splice seamlessly"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
        std::fs::remove_file(path).ok();
    }
}

/// Tentpole acceptance (degradation arm): an injected readahead-thread
/// death (`kill_ra=2`) downgrades the run to demand paging — counted in
/// `IoStats::degraded` — while the trajectory stays bit-identical on
/// both the synchronous and the pipelined driver.
#[test]
fn readahead_death_degrades_but_never_diverges() {
    let ds = dense_ds(2400, 6, 29);
    let clean = run_experiment(&cfg(SolverKind::Saga, 100), &ds).unwrap();
    for depth in [0usize, 2] {
        let spec = FaultSpec::parse("kill_ra=2").unwrap();
        let (path, faulted) =
            faulty_copy(&ds, ds.file_bytes() / 4, Some(spec), generous_retry());
        let mut c = cfg(SolverKind::Saga, 100);
        c.prefetch_depth = depth;
        c.storage.readahead_pages = 32;
        let hurt = run_experiment(&c, &faulted).unwrap();
        assert_eq!(clean.w, hurt.w, "depth={depth}: degradation must not change bytes");
        assert_eq!(
            clean.final_objective.to_bits(),
            hurt.final_objective.to_bits(),
            "depth={depth}: objective"
        );
        let io = faulted.io_stats();
        assert!(io.degraded >= 1, "depth={depth}: the downgrade must be counted ({io:?})");
        std::fs::remove_file(path).ok();
    }
}

/// Tentpole acceptance (permanent-corruption arm): a bit-flip on *every*
/// fetch exhausts the quarantine/refetch budget and surfaces as the
/// typed [`Error::Corrupt`] — through both drivers, never a panic and
/// never a silently corrupted batch.
#[test]
fn persistent_corruption_is_a_typed_error_not_a_panic() {
    let ds = dense_ds(1200, 6, 5);
    for depth in [0usize, 2] {
        let spec = FaultSpec::parse("seed=1,corrupt=1.0").unwrap();
        let fast = RetryPolicy {
            max_attempts: 3,
            base_backoff_us: 1,
            max_backoff_us: 2,
            op_timeout_ms: 30_000,
        };
        let (path, faulted) = faulty_copy(&ds, ds.file_bytes() / 4, Some(spec), fast);
        let mut c = cfg(SolverKind::Mbsgd, 100);
        c.prefetch_depth = depth;
        match run_experiment(&c, &faulted) {
            Err(Error::Corrupt { msg, .. }) => {
                assert!(msg.contains("checksum"), "depth={depth}: {msg}");
            }
            other => panic!("depth={depth}: expected Error::Corrupt, got {other:?}"),
        }
        std::fs::remove_file(path).ok();
    }
}

/// Satellite property test: the retry backoff schedule is a pure
/// function of `(policy, seed)` — bit-equal on replay, capped by
/// `max_backoff_us`, never below the exponential floor — across a grid
/// of policies and seeds. This is what makes fault-injected runs
/// deterministic enough to diff.
#[test]
fn backoff_schedule_is_pure_capped_and_floored_across_policies() {
    for base in [1u64, 50, 400] {
        for cap in [base, base * 8, 5_000] {
            for attempts in [1u32, 2, 6, 40] {
                let policy = RetryPolicy {
                    max_attempts: attempts,
                    base_backoff_us: base,
                    max_backoff_us: cap,
                    op_timeout_ms: 0,
                };
                for seed in [0u64, 1, 42, 0xdead_beef, u64::MAX] {
                    let a = policy.backoff_schedule(seed);
                    let b = policy.backoff_schedule(seed);
                    assert_eq!(a, b, "base={base} cap={cap} attempts={attempts} seed={seed}");
                    assert_eq!(a.len(), attempts.saturating_sub(1) as usize);
                    for (i, &us) in a.iter().enumerate() {
                        assert!(us <= cap, "sleep {us}us over cap {cap}");
                        let floor = (base << i.min(32)).min(cap);
                        assert!(us >= floor, "sleep {us}us under floor {floor}");
                    }
                }
            }
        }
    }
}
