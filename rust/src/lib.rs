//! # samplex — Faster Learning by Reduction of Data Access Time
//!
//! A production reproduction of Chauhan, Sharma & Dahiya, *"Faster Learning by
//! Reduction of Data Access Time"* (Applied Intelligence, 2018;
//! DOI 10.1007/s10489-018-1235-x).
//!
//! The paper's observation: `training time = data-access time + processing
//! time` (eq. 1), and the access component — dominated by per-mini-batch
//! seek/rotational-latency/transfer costs — is controlled entirely by the
//! *sampling technique*. Replacing random sampling (RS) of mini-batches with
//! **cyclic/sequential sampling (CS)** or **systematic sampling (SS)**, both
//! of which fetch contiguous runs of rows, preserves convergence (Theorem 1)
//! while cutting training time by 1.5×–6×.
//!
//! ## The workspace
//!
//! This crate is a **facade**: since the workspace split the implementation
//! lives in three layered member crates, re-exported here at their
//! historical single-crate paths so examples, benches, tests and downstream
//! users compile unchanged:
//!
//! ```text
//!   samplex-service   the `samplex` binary: CLI + `samplex serve` daemon
//!        │                 (multi-tenant jobs over one shared data plane)
//!   samplex (this)    facade: old `samplex::…` paths
//!        │
//!   samplex-compute   solvers/ backend/ runtime/ train/ config/ math::chunked
//!        │
//!   samplex-data      storage/ data/ pipeline/ sampling/ math kernels,
//!        │            aligned, rng, error, testing
//!   samplex-obs       stats (IoStats/AccessCost), metrics/, obs/ tracing
//! ```
//!
//! Each member depends only on members below it; the observability structs
//! sit at the bottom so every layer can report through them without cycles.
//! `README.md` ("Architecture") and `INVARIANTS.md` map the machine-checked
//! invariant rules (R1–R8, `tools/samplex-lint`) onto the members they bind
//! to.
//!
//! ## Architecture (three layers, Python never on the training path)
//!
//! * **Layer 3 (this workspace)** — the data-pipeline coordinator: a
//!   **layout-polymorphic data plane** ([`data::Dataset`]: row-major
//!   [`data::DenseDataset`] for the paper's dense sets, CSR
//!   [`data::CsrDataset`] for high-dimensional sparse ones, with LIBSVM
//!   parsed sparse-native in O(nnz), and **out-of-core**
//!   [`data::PagedDataset`] serving either on-disk layout through a
//!   byte-budgeted page store), samplers, block-device storage model
//!   + access-time simulator (charging sparse fetches by nnz-proportional
//!   byte extents), a **zero-copy, persistent batch engine**
//!   ([`pipeline::prefetch`]: one reader thread per experiment; epochs
//!   arrive as messages; contiguous CS/SS batches flow to the solvers as
//!   [`pipeline::BatchPayload::Borrowed`] range views — one borrowed slice
//!   for dense, three for CSR — with zero feature or index bytes copied,
//!   scattered RS batches pay a real gather counted in bytes), a
//!   **parallel compute plane** ([`runtime::pool`] + [`math::chunked`]:
//!   a persistent zero-dependency worker pool that every O(rows·cols)/
//!   O(nnz) full-dataset sweep — objective, SVRG full gradient, Nesterov
//!   optimum, §5 data-parallel epochs — runs through as fixed-geometry
//!   chunks folded serially in chunk order, so results are bit-identical
//!   at every thread count), the five solvers (SAG/SAGA/SVRG/SAAG-II/
//!   MBSGD) stepping through one [`data::BatchView`] seam (with lazy l2
//!   for sparse MBSGD), constant-step and backtracking line search,
//!   metrics that decompose training time into access vs compute (plus
//!   copied-vs-borrowed byte traffic), and the experiment harness that
//!   regenerates every table and figure of the paper.
//!
//! ## The paging layer: simulated vs real access time
//!
//! ```text
//!                       RowSelection (CS / SS / RS)
//!                                  │
//!                ┌─────────────────┴──────────────────┐
//!                ▼ (model)                            ▼ (perform)
//!   storage::AccessSimulator             data::PagedDataset
//!   BlockMap → LruCache → device         elem range → storage::PageStore
//!   profile: seek + rot + transfer       ┌──────────────────────────────┐
//!   ⇒ AccessCost (simulated s,           │ byte-budgeted resident pool  │
//!     seeks, blocks, cache hits)         │ (LruCache-evicted Arc pages) │
//!                                        │ hit → borrow   miss → fault  │
//!                                        │ runs = 1 seek + 1 seq read   │
//!                                        └──────────────────────────────┘
//!                                        ⇒ IoStats (real bytes, syscalls,
//!                                          faults, amplification, MB/s)
//! ```
//!
//! The **simulator is authoritative for the paper's access-time numbers**
//! (deterministic, can impersonate HDD/SSD/RAM anywhere); the **page store
//! is authoritative for out-of-core feasibility** and for the physical
//! contiguous-vs-dispersed gap on the host's actual storage. Every
//! [`TrainReport`](train::TrainReport) carries both, and the harness CSV
//! prints them side by side. Contiguous CS/SS batches resolve to maximal
//! page runs (one sequential read each; a batch inside one resident page
//! is pinned zero-copy out of the refcounted page), scattered RS batches
//! fault their pages one by one — so trajectories stay **bit-identical**
//! to the in-core stores while datasets larger than RAM train under a
//! `--memory-budget` as small as one page.
//!
//! The resident pool is **shard-locked** (per-shard locks + one atomic
//! stats block — no global store mutex), and because every sampling
//! schedule is a pure function of `(seed, epoch)` the
//! [`storage::pagestore::Readahead`] thread can prefault the *exact*
//! upcoming pages within a `--readahead-pages` window, overlapping disk
//! time with solver compute: demand faults (and the consumer-visible
//! `stall_s`) drop to zero for contiguous access at healthy budgets while
//! trajectories stay bit-identical with readahead on or off.
//!
//! Since the service split, one warm page store can be **shared by many
//! jobs**: `samplex serve` keys stores by dataset path, hands every job a
//! per-job stats view ([`storage::PageStore::job_view`]) so shared totals
//! and per-tenant deltas stay separately exact, and admits jobs against a
//! global memory budget instead of letting tenants thrash one cache.
//!
//! ## Reproducibility and the compute plane
//!
//! Pooled reductions follow one rule — chunk geometry fixed by the data,
//! per-chunk partials in isolated slots, one serial fold in chunk order —
//! so every sweep is **bit-identical for any pool size** (CI runs the
//! whole suite at default parallelism *and* pinned to one thread). Thread
//! count is a pure wall-clock knob: pin it with `SAMPLEX_POOL_THREADS=1`,
//! `pool_threads = 1` in a config, or
//! [`runtime::pool::set_parallelism`]`(1)` when reproducing paper
//! figures.
//!
//! The hot kernels underneath those sweeps ([`math::simd`]) are
//! **runtime-dispatched**: one startup CPU-feature probe selects the AVX2
//! (x86-64), NEON (aarch64), or portable-scalar kernel set, cached in a
//! function table. Every set performs the same arithmetic in the same
//! order — fixed virtual lane counts, fixed reduction trees, shared
//! remainder handling, no FMA contraction — so the dispatch choice is also
//! a pure wall-clock knob: trajectories are **bit-identical scalar vs
//! SIMD** (pin with `SAMPLEX_FORCE_SCALAR=1` or `--force-scalar`; CI runs
//! the suite both ways and the determinism suite compares full solver
//! trajectories across sets). Feature regions, decoded pages, and solver
//! state live in 64-byte [`aligned::AlignedVec`] buffers so vector loads
//! never split cache lines, and full dense sweeps are cache-blocked past
//! 4 K columns (`math::logistic`) so `w` stays L1/L2-resident.
//! * **Layer 2** — JAX model (`python/compile/model.py`): mini-batch
//!   gradient/objective and fused solver update steps, AOT-lowered once per
//!   (batch, features) shape to HLO text under `artifacts/`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`): the fused
//!   logistic-gradient hot-spot, tiled so each row tile of `X` streams
//!   through VMEM once.
//!
//! The [`runtime`] module loads the artifacts through the PJRT C API (`xla`
//! crate, behind the optional `pjrt` cargo feature — the default build is
//! fully offline with zero dependencies) and [`backend::PjrtBackend`]
//! executes them from the solver hot path; [`math`] is a bit-careful native
//! mirror used as cross-check oracle and portable fallback.
//!
//! ## Machine-checked invariants (`samplex-lint`)
//!
//! The concurrency and determinism claims above are not just prose: the
//! workspace ships `tools/samplex-lint`, a zero-dependency static checker
//! run in CI (`cargo run -p samplex-lint -- --workspace .`) that enforces
//!
//! * **no-panic-plane** — no `panic!` / `unwrap()` / `expect(` /
//!   `unreachable!` in the data plane (`data/`, `storage/`, `pipeline/`,
//!   `math/chunked.rs`): a poisoned lock or a torn shard must surface as
//!   a typed [`Error`], never tear down a worker mid-epoch;
//! * **lock-discipline** — no disk I/O or page decode inside a
//!   shard-lock scope in `storage/pagestore.rs`, and no nested lock
//!   acquisition (the fault protocol is reserve → drop lock → read →
//!   re-lock → publish);
//! * **determinism** — no `HashMap`/`HashSet` iteration, clocks, or
//!   thread identity in the bit-identical modules (`math/chunked.rs`,
//!   `train/parallel.rs`, `backend/native.rs`);
//! * **atomics-audit** — every `Ordering::Relaxed` is an annotated stats
//!   counter, never a synchronization flag;
//! * **safety-comments** — every `unsafe` carries a `// SAFETY:` account;
//! * **simd-dispatch** — `#[target_feature]` kernels are defined in
//!   `math/simd/` only and reached only through the dispatched
//!   [`math::simd::KernelSet`] table, never called directly (calling one
//!   on a CPU without the feature is UB; the table is probed once);
//! * **io-discipline** — raw `.read_exact(`/`.seek(` calls in `storage/`
//!   live only in [`storage::retry`], so every byte off disk passes
//!   through the bounded-retry + checksum recovery path;
//! * **clock-discipline** — raw `Instant::now` / `SystemTime::now` reads
//!   live only in `metrics/` and `obs/`: every other module measures time
//!   through the [`metrics::timer::monotonic_ns`] seam (or not at all),
//!   so wall-clock can never silently leak into a deterministic plane.
//!
//! The rules match on path suffixes (`storage/pagestore.rs` under *any*
//! member), so they survived the crate split unchanged. `INVARIANTS.md`
//! at the repo root documents each rule, which workspace member it binds
//! to, the escape hatch (a per-site `allow(rule) -- reason` annotation),
//! and the Miri / ThreadSanitizer CI jobs that test the same invariants
//! dynamically.
//!
//! ## Observability (`samplex-trace`)
//!
//! The [`obs`] module measures eq. (1) instead of inferring it: when
//! tracing is armed (`samplex train --trace out.json`), every phase
//! boundary — page fault, checksum verify, decode, batch assemble,
//! readahead prefault, prefetch stall, chunked sweep, solver step,
//! checkpoint write — records a span into a lock-free per-thread ring
//! buffer, timestamped through the single
//! [`metrics::timer::monotonic_ns`] clock seam. Exporters turn the rings
//! into a Chrome `trace_event` JSON (open in `chrome://tracing` /
//! Perfetto), an ASCII per-thread "overlap map", log-bucketed latency
//! histograms (fault latency, batch wait, retry backoff), and a per-epoch
//! `access_s` / `compute_s` / `overlap_s` attribution carried in
//! [`train::TrainReport`] and the harness CSV. Disarmed, the plane costs
//! nothing: no timestamps, no allocation, no control-flow difference —
//! the determinism suite pins traced vs untraced trajectories
//! bit-identical.
//!
//! ## Quick start
//!
//! ```no_run
//! use samplex::prelude::*;
//!
//! let ds = samplex::data::registry::generate("covtype-mini", 42).unwrap();
//! let cfg = ExperimentConfig::quick("covtype-mini", samplex::solvers::SolverKind::Mbsgd,
//!                                   SamplingKind::Ss, 500);
//! let report = samplex::train::run_experiment(&cfg, &ds).unwrap();
//! println!("{}", report.summary());
//! ```

pub use samplex_compute::{
    backend, bench_harness, config, math, runtime, solvers, train,
};
pub use samplex_data::{
    aligned, data, error, pipeline, rng, sampling, storage, testing,
};
pub use samplex_obs::{metrics, obs, stats};

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::backend::{ComputeBackend, NativeBackend};
    pub use crate::config::{BackendKind, ExperimentConfig, StepKind, StorageConfig};
    pub use crate::data::csr::CsrDataset;
    pub use crate::data::dense::DenseDataset;
    pub use crate::data::Dataset;
    pub use crate::error::{Error, Result};
    pub use crate::sampling::SamplingKind;
    pub use crate::solvers::SolverKind;
    pub use crate::storage::profile::DeviceProfile;
    pub use crate::train::{run_experiment, TrainReport};
}
