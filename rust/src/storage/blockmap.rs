//! Row → block-extent geometry for the `.sxb` layout.
//!
//! Data is read block-wise, not content-wise (paper §1): a mini-batch's cost
//! is determined by *which blocks* its rows live in. The block map converts
//! a [`RowSelection`] into the ordered set of blocks touched, preserving the
//! selection's access order so the simulator can detect contiguous runs.

use crate::data::batch::RowSelection;

/// Geometry of a row-major dataset on a blocked device.
#[derive(Debug, Clone, Copy)]
pub struct BlockMap {
    /// Byte offset of feature row 0 (after header + labels in `.sxb`).
    pub x_base: u64,
    /// Bytes per feature row (`cols * 4`).
    pub row_bytes: u64,
    /// Device block size.
    pub block_bytes: u64,
}

impl BlockMap {
    /// Geometry for `ds` on a device with `block_bytes` blocks.
    pub fn for_dataset(ds: &crate::data::dense::DenseDataset, block_bytes: u64) -> Self {
        let (lo, hi) = ds.row_extent(0);
        BlockMap { x_base: lo, row_bytes: hi - lo, block_bytes }
    }

    /// Inclusive block-id range `[lo, hi]` containing row `r`.
    #[inline]
    pub fn blocks_for_row(&self, r: usize) -> (u64, u64) {
        let lo_byte = self.x_base + r as u64 * self.row_bytes;
        let hi_byte = lo_byte + self.row_bytes - 1;
        (lo_byte / self.block_bytes, hi_byte / self.block_bytes)
    }

    /// Inclusive block range for contiguous rows `[start, end)`.
    #[inline]
    pub fn blocks_for_range(&self, start: usize, end: usize) -> (u64, u64) {
        debug_assert!(end > start);
        let (lo, _) = self.blocks_for_row(start);
        let (_, hi) = self.blocks_for_row(end - 1);
        (lo, hi)
    }

    /// Ordered, batch-deduplicated list of blocks touched by `sel`.
    ///
    /// Order follows the selection's row order (the physical access order);
    /// a block is listed once even if several selected rows share it — the
    /// second row's bytes are already in the drive's track buffer / page.
    pub fn blocks_for_selection(&self, sel: &RowSelection) -> Vec<u64> {
        match sel {
            RowSelection::Contiguous { start, end } => {
                let (lo, hi) = self.blocks_for_range(*start, *end);
                (lo..=hi).collect()
            }
            RowSelection::Scattered(rows) => {
                let mut out = Vec::with_capacity(rows.len());
                let mut seen = std::collections::HashSet::with_capacity(rows.len());
                for &r in rows {
                    let (lo, hi) = self.blocks_for_row(r as usize);
                    for b in lo..=hi {
                        if seen.insert(b) {
                            out.push(b);
                        }
                    }
                }
                out
            }
        }
    }

    /// Group an *ordered* block list into maximal runs of consecutive ids.
    /// Each run costs one positioning (seek + rotational + IO issue).
    pub fn coalesce_runs(blocks: &[u64]) -> Vec<(u64, u64)> {
        let mut runs = Vec::new();
        let mut iter = blocks.iter().copied();
        let Some(first) = iter.next() else {
            return runs;
        };
        let (mut lo, mut hi) = (first, first);
        for b in iter {
            if b == hi + 1 {
                hi = b;
            } else {
                runs.push((lo, hi));
                lo = b;
                hi = b;
            }
        }
        runs.push((lo, hi));
        runs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseDataset;

    fn map() -> BlockMap {
        // 64-byte rows, 256-byte blocks -> 4 rows per block, x_base 0 for
        // easy arithmetic
        BlockMap { x_base: 0, row_bytes: 64, block_bytes: 256 }
    }

    #[test]
    fn rows_share_blocks() {
        let m = map();
        assert_eq!(m.blocks_for_row(0), (0, 0));
        assert_eq!(m.blocks_for_row(3), (0, 0));
        assert_eq!(m.blocks_for_row(4), (1, 1));
    }

    #[test]
    fn row_spanning_two_blocks() {
        let m = BlockMap { x_base: 0, row_bytes: 100, block_bytes: 256 };
        // row 2: bytes [200, 300) spans blocks 0 and 1
        assert_eq!(m.blocks_for_row(2), (0, 1));
    }

    #[test]
    fn x_base_offset_respected() {
        let m = BlockMap { x_base: 250, row_bytes: 64, block_bytes: 256 };
        // row 0: bytes [250, 314) spans blocks 0..=1
        assert_eq!(m.blocks_for_row(0), (0, 1));
    }

    #[test]
    fn contiguous_selection_is_one_run() {
        let m = map();
        let sel = RowSelection::Contiguous { start: 0, end: 16 };
        let blocks = m.blocks_for_selection(&sel);
        assert_eq!(blocks, vec![0, 1, 2, 3]);
        assert_eq!(BlockMap::coalesce_runs(&blocks), vec![(0, 3)]);
    }

    #[test]
    fn scattered_selection_many_runs() {
        let m = map();
        // rows 0, 8, 4 -> blocks 0, 2, 1 in that access order
        let sel = RowSelection::Scattered(vec![0, 8, 4]);
        let blocks = m.blocks_for_selection(&sel);
        assert_eq!(blocks, vec![0, 2, 1]);
        // order preserved: 0 | 2 | 1 -> three runs (head jumps back)
        assert_eq!(BlockMap::coalesce_runs(&blocks), vec![(0, 0), (2, 2), (1, 1)]);
    }

    #[test]
    fn duplicate_rows_dedupe_within_batch() {
        let m = map();
        let sel = RowSelection::Scattered(vec![1, 1, 2]);
        // rows 1,2 share block 0
        assert_eq!(m.blocks_for_selection(&sel), vec![0]);
    }

    #[test]
    fn coalesce_handles_empty_and_single() {
        assert!(BlockMap::coalesce_runs(&[]).is_empty());
        assert_eq!(BlockMap::coalesce_runs(&[5]), vec![(5, 5)]);
        assert_eq!(BlockMap::coalesce_runs(&[5, 6, 7, 9]), vec![(5, 7), (9, 9)]);
    }

    #[test]
    fn for_dataset_uses_sxb_geometry() {
        let d = DenseDataset::new("t", 2, vec![0.0; 20], vec![1.0; 10].iter()
            .enumerate().map(|(i, _)| if i % 2 == 0 { 1.0 } else { -1.0 }).collect())
            .unwrap();
        let m = BlockMap::for_dataset(&d, 4096);
        assert_eq!(m.row_bytes, 8);
        assert_eq!(m.x_base, crate::data::dense::HEADER_BYTES + 40);
    }
}
