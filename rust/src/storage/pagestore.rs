//! Paged, disk-backed feature store — the *real* out-of-core layer.
//!
//! Where [`super::simulator::AccessSimulator`] *models* device time and
//! [`super::reader::DiskSource`] performs whole-batch reads with no
//! residency, the page store is the full OS-page-cache analogue built into
//! the process: the feature region of a `.sxb`/`.sxc` file is split into
//! fixed-size pages that are read on demand into a **byte-budgeted**
//! resident pool and evicted via the same [`LruCache`] slab machinery the
//! simulator uses. Every access is accounted in [`IoStats`] — real bytes
//! read, read syscalls, page faults/hits, delivered bytes and wall read
//! time — so the paper's contiguous-vs-dispersed gap is measurable on
//! actual file I/O, next to the simulator's idealized numbers.
//!
//! Access-pattern behavior (the paper's §1 claim, reproduced physically):
//!
//! * a contiguous range touching several non-resident pages is served by
//!   **one seek + one sequential read per maximal run** of missing pages;
//! * a scattered access faults its pages individually — one syscall each;
//! * a range that lands inside one *resident* page can be borrowed
//!   zero-copy ([`PageStore::pin_range`]) because pages are refcounted
//!   ([`Arc`]): eviction drops the pool's reference, never the borrower's.
//!
//! Pages are stored *decoded* (f32 elements for dense `.sxb`, deinterleaved
//! `(col_idx, value)` pair arrays for `.sxc`), so borrowing out of a page
//! yields exactly the slices the math kernels consume and results stay
//! bit-identical to the in-core stores.

use std::collections::HashMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::storage::cache::{LruCache, Touch};

/// Lifetime I/O statistics of one page store — the real-file analogue of
/// [`super::simulator::AccessCost`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IoStats {
    /// Bytes physically read from the file (page granularity).
    pub bytes_read: u64,
    /// Read syscalls issued (one per maximal run of faulted pages).
    pub read_calls: u64,
    /// Pages faulted in from disk.
    pub page_faults: u64,
    /// Page touches served from the resident pool.
    pub page_hits: u64,
    /// Bytes actually delivered to callers (the useful payload).
    pub bytes_requested: u64,
    /// Wall seconds spent inside read syscalls.
    pub read_s: f64,
}

impl IoStats {
    /// `bytes_read / bytes_requested` — how many bytes the page
    /// granularity forced off the device per byte the caller wanted.
    pub fn read_amplification(&self) -> f64 {
        if self.bytes_requested == 0 {
            0.0
        } else {
            self.bytes_read as f64 / self.bytes_requested as f64
        }
    }

    /// Achieved read throughput in MB/s (0 when nothing was read).
    pub fn mb_per_s(&self) -> f64 {
        if self.read_s <= 0.0 {
            0.0
        } else {
            self.bytes_read as f64 / 1e6 / self.read_s
        }
    }

    /// Counters accumulated since `base` was captured (page stores are
    /// shared across experiment arms; reports want per-arm deltas).
    pub fn delta_since(&self, base: &IoStats) -> IoStats {
        IoStats {
            bytes_read: self.bytes_read - base.bytes_read,
            read_calls: self.read_calls - base.read_calls,
            page_faults: self.page_faults - base.page_faults,
            page_hits: self.page_hits - base.page_hits,
            bytes_requested: self.bytes_requested - base.bytes_requested,
            read_s: self.read_s - base.read_s,
        }
    }
}

impl std::ops::AddAssign for IoStats {
    fn add_assign(&mut self, rhs: Self) {
        self.bytes_read += rhs.bytes_read;
        self.read_calls += rhs.read_calls;
        self.page_faults += rhs.page_faults;
        self.page_hits += rhs.page_hits;
        self.bytes_requested += rhs.bytes_requested;
        self.read_s += rhs.read_s;
    }
}

/// How the raw page bytes decode into math-kernel-ready arrays.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageLayout {
    /// Little-endian f32 elements (the `.sxb` feature region).
    DenseF32,
    /// Packed `(u32 col_idx, f32 value)` pairs (the `.sxc` payload region),
    /// deinterleaved into two arrays at decode time.
    IdxValPairs,
}

impl PageLayout {
    /// Bytes per stored element (f32 = 4; index+value pair = 8).
    pub const fn elem_bytes(self) -> u64 {
        match self {
            PageLayout::DenseF32 => 4,
            PageLayout::IdxValPairs => 8,
        }
    }
}

/// One decoded, refcounted page of the feature region.
#[derive(Debug)]
pub enum Page {
    /// Dense f32 elements.
    Dense(Vec<f32>),
    /// Deinterleaved CSR payload: values and their column indices.
    Pairs {
        /// Non-zero values.
        values: Vec<f32>,
        /// Column index of each value.
        col_idx: Vec<u32>,
    },
}

impl Page {
    /// Elements held by this page.
    pub fn len(&self) -> usize {
        match self {
            Page::Dense(x) => x.len(),
            Page::Pairs { values, .. } => values.len(),
        }
    }

    /// True when the page holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The dense element array (panics on a pairs page — layout is fixed
    /// per store, so this is a programming error, not a data error).
    pub fn dense(&self) -> &[f32] {
        match self {
            Page::Dense(x) => x,
            Page::Pairs { .. } => panic!("dense() on a pairs page"),
        }
    }

    /// The pair arrays `(values, col_idx)` (panics on a dense page).
    pub fn pairs(&self) -> (&[f32], &[u32]) {
        match self {
            Page::Pairs { values, col_idx } => (values, col_idx),
            Page::Dense(_) => panic!("pairs() on a dense page"),
        }
    }
}

/// Fixed-size paged view over one file region, with a byte-budgeted
/// resident pool, LRU eviction and lifetime [`IoStats`].
///
/// Element addressing: the region holds `n_elems` elements of
/// `layout.elem_bytes()` bytes each, starting at absolute file offset
/// `region_base`. Page `p` covers elements
/// `[p * elems_per_page, (p+1) * elems_per_page)` (the last page may be
/// short).
#[derive(Debug)]
pub struct PageStore {
    file: File,
    path: String,
    layout: PageLayout,
    region_base: u64,
    n_elems: u64,
    elems_per_page: u64,
    page_bytes: u64,
    budget_bytes: u64,
    resident: HashMap<u64, Arc<Page>>,
    lru: LruCache,
    raw: Vec<u8>,
    /// Exclusive upper bound for decoded `col_idx` values (pairs layout
    /// only; `u32::MAX` = unchecked). Catches payload corruption at fault
    /// time with a typed error instead of an out-of-bounds panic deep in
    /// a math kernel.
    idx_bound: u32,
    /// Lifetime I/O counters.
    pub stats: IoStats,
}

impl PageStore {
    /// Build over the region `[region_base, region_base + n_elems * elem)`
    /// of `file`. `page_bytes` must be a positive multiple of the layout's
    /// element size; `budget_bytes` caps the resident pool (a budget below
    /// one page keeps nothing resident — every access faults).
    pub fn new(
        file: File,
        path: impl AsRef<Path>,
        layout: PageLayout,
        region_base: u64,
        n_elems: u64,
        page_bytes: u64,
        budget_bytes: u64,
    ) -> Result<Self> {
        if page_bytes == 0 || page_bytes % layout.elem_bytes() != 0 {
            return Err(Error::Config(format!(
                "page size {page_bytes} must be a positive multiple of the \
                 element size {}",
                layout.elem_bytes()
            )));
        }
        let capacity_pages = (budget_bytes / page_bytes) as usize;
        Ok(PageStore {
            file,
            path: path.as_ref().display().to_string(),
            layout,
            region_base,
            n_elems,
            elems_per_page: page_bytes / layout.elem_bytes(),
            page_bytes,
            budget_bytes,
            resident: HashMap::new(),
            lru: LruCache::new(capacity_pages),
            raw: Vec::new(),
            idx_bound: u32::MAX,
            stats: IoStats::default(),
        })
    }

    /// Validate every decoded `col_idx` against `bound` (exclusive) from
    /// now on — corrupt payload pairs then fault with [`Error::Corrupt`]
    /// carrying the offending byte offset, mirroring the typed header
    /// checks.
    pub fn set_idx_bound(&mut self, bound: u32) {
        self.idx_bound = bound;
    }

    /// Total pages covering the region.
    pub fn n_pages(&self) -> u64 {
        self.n_elems.div_ceil(self.elems_per_page)
    }

    /// Elements in the region.
    pub fn n_elems(&self) -> u64 {
        self.n_elems
    }

    /// Configured page size in bytes.
    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    /// Configured resident-pool budget in bytes.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Pages currently resident.
    pub fn resident_pages(&self) -> usize {
        self.resident.len()
    }

    /// Resident-pool hit rate over the store's lifetime.
    pub fn hit_rate(&self) -> f64 {
        let total = self.stats.page_hits + self.stats.page_faults;
        if total == 0 {
            0.0
        } else {
            self.stats.page_hits as f64 / total as f64
        }
    }

    /// Fault pages `[lo, hi]` (inclusive, consecutive) with **one** seek +
    /// read, decode them, and return them in page order. Does not insert
    /// into the pool — the caller decides residency.
    fn read_run(&mut self, lo: u64, hi: u64) -> Result<Vec<Arc<Page>>> {
        let first_elem = lo * self.elems_per_page;
        let last_elem = ((hi + 1) * self.elems_per_page).min(self.n_elems);
        let byte_lo = self.region_base + first_elem * self.layout.elem_bytes();
        let nbytes = (last_elem - first_elem) * self.layout.elem_bytes();
        self.raw.resize(nbytes as usize, 0);
        let sw = std::time::Instant::now();
        self.file.seek(SeekFrom::Start(byte_lo))?;
        self.file.read_exact(&mut self.raw).map_err(|e| Error::Corrupt {
            path: self.path.clone(),
            offset: byte_lo,
            msg: format!("short read of {nbytes} bytes: {e}"),
        })?;
        self.stats.read_s += sw.elapsed().as_secs_f64();
        self.stats.read_calls += 1;
        self.stats.bytes_read += nbytes;
        self.stats.page_faults += hi - lo + 1;
        let mut out = Vec::with_capacity((hi - lo + 1) as usize);
        for id in lo..=hi {
            let a = ((id * self.elems_per_page - first_elem) * self.layout.elem_bytes()) as usize;
            let b = ((((id + 1) * self.elems_per_page).min(self.n_elems) - first_elem)
                * self.layout.elem_bytes()) as usize;
            let page = self.decode(&self.raw[a..b]);
            if let Page::Pairs { col_idx, .. } = &page {
                if let Some(k) = col_idx.iter().position(|&c| c >= self.idx_bound) {
                    let elem = id * self.elems_per_page + k as u64;
                    return Err(Error::Corrupt {
                        path: self.path.clone(),
                        offset: self.region_base + elem * self.layout.elem_bytes(),
                        msg: format!(
                            "col_idx {} >= column bound {} at element {elem}",
                            col_idx[k], self.idx_bound
                        ),
                    });
                }
            }
            out.push(Arc::new(page));
        }
        Ok(out)
    }

    fn decode(&self, raw: &[u8]) -> Page {
        match self.layout {
            PageLayout::DenseF32 => {
                let mut x = Vec::with_capacity(raw.len() / 4);
                for ch in raw.chunks_exact(4) {
                    x.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
                }
                Page::Dense(x)
            }
            PageLayout::IdxValPairs => {
                let n = raw.len() / 8;
                let mut values = Vec::with_capacity(n);
                let mut col_idx = Vec::with_capacity(n);
                for ch in raw.chunks_exact(8) {
                    col_idx.push(u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
                    values.push(f32::from_le_bytes([ch[4], ch[5], ch[6], ch[7]]));
                }
                Page::Pairs { values, col_idx }
            }
        }
    }

    /// Insert a freshly faulted page into the pool, evicting per budget.
    /// With a zero-capacity pool (budget below one page) nothing is kept.
    fn install(&mut self, id: u64, page: Arc<Page>) {
        if self.lru.capacity() == 0 {
            return;
        }
        match self.lru.touch_evicting(id) {
            Touch::Hit => {
                // already tracked (possible when a caller re-faults a page
                // it raced out of `resident`); refresh the buffer
                self.resident.insert(id, page);
            }
            Touch::Miss { evicted } => {
                if let Some(ev) = evicted {
                    self.resident.remove(&ev);
                }
                self.resident.insert(id, page);
            }
        }
    }

    /// Touch a resident page: promote + count a hit and return its buffer.
    fn touch_resident(&mut self, id: u64) -> Option<Arc<Page>> {
        let page = self.resident.get(&id).map(Arc::clone)?;
        let _ = self.lru.touch_evicting(id);
        self.stats.page_hits += 1;
        Some(page)
    }

    /// If the non-empty element range `[elem_lo, elem_hi)` lies inside a
    /// single page, fault that page (if needed) and return it with the
    /// range's offset inside the page — the zero-copy borrow path for
    /// batches that land in one page. Returns `None` when the range is
    /// empty or spans pages.
    pub fn pin_range(&mut self, elem_lo: u64, elem_hi: u64) -> Result<Option<(Arc<Page>, usize)>> {
        if elem_hi <= elem_lo {
            return Ok(None);
        }
        debug_assert!(elem_hi <= self.n_elems);
        let p_lo = elem_lo / self.elems_per_page;
        let p_hi = (elem_hi - 1) / self.elems_per_page;
        if p_lo != p_hi {
            return Ok(None);
        }
        self.stats.bytes_requested += (elem_hi - elem_lo) * self.layout.elem_bytes();
        let page = match self.touch_resident(p_lo) {
            Some(p) => p,
            None => {
                let mut run = self.read_run(p_lo, p_lo)?;
                let p = run.pop().expect("one page");
                self.install(p_lo, Arc::clone(&p));
                p
            }
        };
        Ok(Some((page, (elem_lo - p_lo * self.elems_per_page) as usize)))
    }

    /// Visit the element range `[elem_lo, elem_hi)` page by page, in
    /// order. `f` receives each page plus the covered sub-range *local to
    /// that page* (element indices). Missing pages are faulted in maximal
    /// consecutive runs — one seek + one sequential read per run — which is
    /// exactly how contiguous CS/SS selections earn their cost advantage on
    /// real files. Pages are refcounted, so a range larger than the budget
    /// is still visited correctly while the pool churns underneath.
    pub fn with_range<F>(&mut self, elem_lo: u64, elem_hi: u64, mut f: F) -> Result<()>
    where
        F: FnMut(&Page, usize, usize),
    {
        if elem_hi <= elem_lo {
            return Ok(());
        }
        debug_assert!(elem_hi <= self.n_elems, "range past region end");
        self.stats.bytes_requested += (elem_hi - elem_lo) * self.layout.elem_bytes();
        let epp = self.elems_per_page;
        let p_lo = elem_lo / epp;
        let p_hi = (elem_hi - 1) / epp;
        // pass 1: classify, promoting hits and collecting their buffers
        let mut pages: Vec<Option<Arc<Page>>> = vec![None; (p_hi - p_lo + 1) as usize];
        let mut misses: Vec<u64> = Vec::new();
        for id in p_lo..=p_hi {
            match self.touch_resident(id) {
                Some(p) => pages[(id - p_lo) as usize] = Some(p),
                None => misses.push(id),
            }
        }
        // pass 2: fault the misses in maximal consecutive runs
        let mut i = 0;
        while i < misses.len() {
            let run_lo = misses[i];
            let mut j = i;
            while j + 1 < misses.len() && misses[j + 1] == misses[j] + 1 {
                j += 1;
            }
            let run_hi = misses[j];
            let faulted = self.read_run(run_lo, run_hi)?;
            for (k, page) in faulted.into_iter().enumerate() {
                let id = run_lo + k as u64;
                self.install(id, Arc::clone(&page));
                pages[(id - p_lo) as usize] = Some(page);
            }
            i = j + 1;
        }
        // pass 3: visit in element order
        for id in p_lo..=p_hi {
            let page = pages[(id - p_lo) as usize].as_ref().expect("page resolved");
            let first = id * epp;
            let last = (first + epp).min(self.n_elems);
            let lo = elem_lo.max(first) - first;
            let hi = elem_hi.min(last) - first;
            f(page, lo as usize, hi as usize);
        }
        Ok(())
    }

    /// Drop every resident page (counters preserved) — e.g. to cold-start
    /// an experiment arm.
    pub fn drop_pool(&mut self) {
        self.resident.clear();
        self.lru.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    static UNIQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

    /// A file whose "region" is `n` little-endian f32s `0.0, 1.0, 2.0, …`
    /// starting at byte offset `base`.
    fn dense_file(base: u64, n: u64) -> (std::path::PathBuf, File) {
        let uniq = UNIQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let p = std::env::temp_dir().join(format!(
            "pagestore_{}_{uniq}_{base}_{n}.bin",
            std::process::id()
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(&vec![0xAAu8; base as usize]).unwrap();
        for i in 0..n {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        f.flush().unwrap();
        (p.clone(), std::fs::File::open(&p).unwrap())
    }

    fn store(
        base: u64,
        n: u64,
        page_bytes: u64,
        budget_bytes: u64,
    ) -> (std::path::PathBuf, PageStore) {
        let (p, f) = dense_file(base, n);
        let s = PageStore::new(f, &p, PageLayout::DenseF32, base, n, page_bytes, budget_bytes)
            .unwrap();
        (p, s)
    }

    #[test]
    fn rejects_bad_page_size() {
        let (p, f) = dense_file(0, 8);
        assert!(PageStore::new(f, &p, PageLayout::DenseF32, 0, 8, 0, 64).is_err());
        let f = std::fs::File::open(&p).unwrap();
        assert!(PageStore::new(f, &p, PageLayout::DenseF32, 0, 8, 6, 64).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn contiguous_range_is_one_sequential_read() {
        // 64 elems, 4 elems per page (16 B), budget for all 16 pages
        let (p, mut s) = store(24, 64, 16, 16 * 16);
        let mut got = Vec::new();
        s.with_range(3, 23, |pg, a, b| got.extend_from_slice(&pg.dense()[a..b]))
            .unwrap();
        let want: Vec<f32> = (3..23).map(|v| v as f32).collect();
        assert_eq!(got, want);
        assert_eq!(s.stats.read_calls, 1, "cold contiguous range = one syscall");
        assert_eq!(s.stats.page_faults, 6); // pages 0..=5
        assert_eq!(s.stats.bytes_read, 6 * 16);
        assert_eq!(s.stats.bytes_requested, 20 * 4);
        assert!(s.stats.read_amplification() > 1.0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn resident_pages_hit_without_io() {
        let (p, mut s) = store(0, 64, 16, 16 * 16);
        let mut sink = 0f32;
        s.with_range(0, 16, |pg, a, b| sink += pg.dense()[a..b].iter().sum::<f32>())
            .unwrap();
        let calls = s.stats.read_calls;
        s.with_range(0, 16, |pg, a, b| sink += pg.dense()[a..b].iter().sum::<f32>())
            .unwrap();
        assert_eq!(s.stats.read_calls, calls, "warm range must not touch the file");
        assert_eq!(s.stats.page_hits, 4);
        assert!(sink > 0.0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn partial_residency_splits_into_runs() {
        let (p, mut s) = store(0, 64, 16, 16 * 16);
        // warm pages 2..=3 (elements 8..16)
        s.with_range(8, 16, |_, _, _| {}).unwrap();
        assert_eq!(s.stats.read_calls, 1);
        // fetch elements 0..32 = pages 0..=7; 2,3 hot -> runs (0,1), (4..7)
        s.with_range(0, 32, |_, _, _| {}).unwrap();
        assert_eq!(s.stats.read_calls, 3);
        assert_eq!(s.stats.page_hits, 2);
        assert_eq!(s.stats.page_faults, 2 + 6);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn budget_bounds_residency_and_forces_refaults() {
        // 16 pages, budget = 4 pages: a full sweep keeps only the last 4
        // resident; the next sweep hits those 4 (ranges classify residency
        // up front, per batch) and must re-fault the other 12
        let (p, mut s) = store(0, 64, 16, 4 * 16);
        s.with_range(0, 64, |_, _, _| {}).unwrap();
        assert_eq!(s.stats.page_faults, 16);
        assert_eq!(s.resident_pages(), 4);
        assert!(s.resident_pages() as u64 * s.page_bytes() <= s.budget_bytes());
        s.with_range(0, 64, |_, _, _| {}).unwrap();
        assert_eq!(s.stats.page_faults, 16 + 12, "evicted pages must re-fault");
        assert_eq!(s.stats.page_hits, 4, "the surviving tail pages hit");
        assert!(s.stats.bytes_read > s.budget_bytes(), "eviction proof");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn zero_budget_keeps_nothing_resident() {
        let (p, mut s) = store(0, 32, 16, 0);
        s.with_range(0, 32, |_, _, _| {}).unwrap();
        s.with_range(0, 32, |_, _, _| {}).unwrap();
        assert_eq!(s.resident_pages(), 0);
        assert_eq!(s.stats.page_hits, 0);
        assert_eq!(s.stats.page_faults, 16);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pin_range_borrows_single_page_and_faults_once() {
        let (p, mut s) = store(0, 64, 16, 16 * 16);
        let (page, off) = s.pin_range(5, 8).unwrap().expect("fits page 1");
        assert_eq!(off, 1);
        assert_eq!(&page.dense()[off..off + 3], &[5.0, 6.0, 7.0]);
        assert_eq!(s.stats.page_faults, 1);
        // second pin of the same page is a pure hit
        let (_page2, _off2) = s.pin_range(4, 8).unwrap().unwrap();
        assert_eq!(s.stats.page_faults, 1);
        assert_eq!(s.stats.page_hits, 1);
        // spanning ranges and empty ranges decline
        assert!(s.pin_range(3, 8).unwrap().is_none());
        assert!(s.pin_range(5, 5).unwrap().is_none());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pinned_page_survives_eviction() {
        // budget = 1 page: pin page 0, then sweep far enough to evict it;
        // the pinned Arc must stay valid and intact
        let (p, mut s) = store(0, 64, 16, 16);
        let (page, off) = s.pin_range(0, 4).unwrap().unwrap();
        s.with_range(16, 64, |_, _, _| {}).unwrap();
        assert!(s.resident_pages() <= 1);
        assert_eq!(&page.dense()[off..off + 4], &[0.0, 1.0, 2.0, 3.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn ragged_last_page_is_short() {
        // 10 elems, 4 per page -> 3 pages, last holds 2
        let (p, mut s) = store(0, 10, 16, 1024);
        assert_eq!(s.n_pages(), 3);
        let mut got = Vec::new();
        s.with_range(0, 10, |pg, a, b| got.extend_from_slice(&pg.dense()[a..b]))
            .unwrap();
        assert_eq!(got.len(), 10);
        assert_eq!(got[9], 9.0);
        assert_eq!(s.stats.bytes_read, 10 * 4, "short last page reads short");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncated_file_yields_typed_corrupt_error() {
        // claim 32 elements but write only 8: faulting past the end must
        // surface a Corrupt error with the offending offset
        let (p, f) = dense_file(0, 8);
        let mut s =
            PageStore::new(f, &p, PageLayout::DenseF32, 0, 32, 16, 1024).unwrap();
        match s.with_range(0, 32, |_, _, _| {}) {
            Err(Error::Corrupt { offset, .. }) => assert!(offset <= 32),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pairs_layout_deinterleaves() {
        let p = std::env::temp_dir().join(format!("pagestore_pairs_{}.bin", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        for i in 0..6u32 {
            f.write_all(&i.to_le_bytes()).unwrap();
            f.write_all(&(i as f32 * 0.5).to_le_bytes()).unwrap();
        }
        f.flush().unwrap();
        let f = std::fs::File::open(&p).unwrap();
        let mut s = PageStore::new(f, &p, PageLayout::IdxValPairs, 0, 6, 16, 1024).unwrap();
        let mut vals = Vec::new();
        let mut idx = Vec::new();
        s.with_range(1, 5, |pg, a, b| {
            let (v, i) = pg.pairs();
            vals.extend_from_slice(&v[a..b]);
            idx.extend_from_slice(&i[a..b]);
        })
        .unwrap();
        assert_eq!(idx, vec![1, 2, 3, 4]);
        assert_eq!(vals, vec![0.5, 1.0, 1.5, 2.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn pairs_page_with_out_of_bounds_index_errors_typed() {
        // 4 pairs, one with col_idx 9 under a bound of 5: the fault must
        // yield Corrupt at that pair's byte offset, not a decoded page
        let p = std::env::temp_dir().join(format!("pagestore_oob_{}.bin", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        for (i, idx) in [0u32, 2, 9, 4].iter().enumerate() {
            f.write_all(&idx.to_le_bytes()).unwrap();
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        f.flush().unwrap();
        let f = std::fs::File::open(&p).unwrap();
        let mut s = PageStore::new(f, &p, PageLayout::IdxValPairs, 0, 4, 16, 1024).unwrap();
        s.set_idx_bound(5);
        match s.with_range(0, 4, |_, _, _| {}) {
            Err(Error::Corrupt { offset, msg, .. }) => {
                assert_eq!(offset, 2 * 8, "offset of the corrupt pair");
                assert!(msg.contains("col_idx 9"), "{msg}");
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn drop_pool_forces_cold_refetch() {
        let (p, mut s) = store(0, 16, 16, 1024);
        s.with_range(0, 16, |_, _, _| {}).unwrap();
        let faults = s.stats.page_faults;
        s.drop_pool();
        assert_eq!(s.resident_pages(), 0);
        s.with_range(0, 16, |_, _, _| {}).unwrap();
        assert!(s.stats.page_faults > faults);
        std::fs::remove_file(p).ok();
    }
}
