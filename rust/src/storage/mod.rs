//! Storage substrate: block-device model, LRU page cache, access-time
//! simulator, and a real `.sxb` file reader for out-of-core training.
//!
//! The paper's eq.(1) decomposes training time into access + processing
//! time, and §1 gives the access model verbatim: *seek time* (head
//! movement), *rotational latency* (sector arrival), *transfer time*
//! (block-wise, never content-wise), with "contiguous data access … faster
//! than dispersed data access in all the cases whether data is stored on
//! RAM, SSD or HDD". This module implements exactly that model so every
//! mini-batch fetch is costed from the *actual byte extents* a sampling
//! technique touches — the substitution for the authors' physical MacBook
//! (DESIGN.md §3).
//!
//! **Cost model across layouts:** the block map knows both the uniform
//! `.sxb` geometry (every row spans `cols * 4` bytes) and the
//! variable-extent `.sxc` geometry (row `r` spans `8 * nnz_r` bytes —
//! value + index — at the offset recorded by `row_ptr`). A sparse dataset
//! is therefore charged by the bytes it would *actually* occupy on disk,
//! scaling with nnz and never with `rows * cols`; empty rows cost nothing.

pub mod blockmap;
pub mod cache;
pub mod profile;
pub mod reader;
pub mod simulator;

pub use blockmap::BlockMap;
pub use cache::LruCache;
pub use profile::DeviceProfile;
pub use simulator::{AccessCost, AccessSimulator};
