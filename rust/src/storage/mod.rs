//! Storage substrate: block-device model, LRU page cache, access-time
//! simulator, and a real `.sxb` file reader for out-of-core training.
//!
//! The paper's eq.(1) decomposes training time into access + processing
//! time, and §1 gives the access model verbatim: *seek time* (head
//! movement), *rotational latency* (sector arrival), *transfer time*
//! (block-wise, never content-wise), with "contiguous data access … faster
//! than dispersed data access in all the cases whether data is stored on
//! RAM, SSD or HDD". This module implements exactly that model so every
//! mini-batch fetch is costed from the *actual byte extents* a sampling
//! technique touches — the substitution for the authors' physical MacBook
//! (DESIGN.md §3).

pub mod blockmap;
pub mod cache;
pub mod profile;
pub mod reader;
pub mod simulator;

pub use blockmap::BlockMap;
pub use cache::LruCache;
pub use profile::DeviceProfile;
pub use simulator::{AccessCost, AccessSimulator};
