//! O(1) LRU block cache — the OS page-cache model.
//!
//! The paper notes that "cache memory strategies also favor the contiguous
//! memory access". The simulator consults this cache before charging device
//! time: re-touching a hot block is free. Capacity is configured in blocks;
//! with datasets far larger than the cache, random sampling thrashes it
//! while cyclic/systematic sweeps get at most cold misses.

use std::collections::HashMap;

const NIL: usize = usize::MAX;

#[derive(Debug, Clone, Copy)]
struct Node {
    key: u64,
    prev: usize,
    next: usize,
}

/// Fixed-capacity LRU set of block ids (slab + intrusive list, O(1) ops).
#[derive(Debug)]
pub struct LruCache {
    map: HashMap<u64, usize>,
    nodes: Vec<Node>,
    head: usize, // most-recently used
    tail: usize, // least-recently used
    free: Vec<usize>,
    capacity: usize,
    /// Lifetime counters.
    pub hits: u64,
    pub misses: u64,
}

impl LruCache {
    /// `capacity` = max resident blocks; 0 disables caching entirely.
    pub fn new(capacity: usize) -> Self {
        LruCache {
            map: HashMap::with_capacity(capacity.min(1 << 20)),
            nodes: Vec::with_capacity(capacity.min(1 << 20)),
            head: NIL,
            tail: NIL,
            free: Vec::new(),
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Number of resident blocks.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Configured capacity in blocks.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn detach(&mut self, idx: usize) {
        let Node { prev, next, .. } = self.nodes[idx];
        if prev != NIL {
            self.nodes[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn attach_front(&mut self, idx: usize) {
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Touch `block`: returns `true` on hit (block was resident; promoted to
    /// MRU), `false` on miss (block inserted, possibly evicting the LRU).
    pub fn touch(&mut self, block: u64) -> bool {
        if self.capacity == 0 {
            self.misses += 1;
            return false;
        }
        if let Some(&idx) = self.map.get(&block) {
            self.hits += 1;
            if self.head != idx {
                self.detach(idx);
                self.attach_front(idx);
            }
            return true;
        }
        self.misses += 1;
        // evict if full
        if self.map.len() == self.capacity {
            let lru = self.tail;
            let key = self.nodes[lru].key;
            self.detach(lru);
            self.map.remove(&key);
            self.free.push(lru);
        }
        let idx = if let Some(idx) = self.free.pop() {
            self.nodes[idx] = Node { key: block, prev: NIL, next: NIL };
            idx
        } else {
            self.nodes.push(Node { key: block, prev: NIL, next: NIL });
            self.nodes.len() - 1
        };
        self.attach_front(idx);
        self.map.insert(block, idx);
        false
    }

    /// Non-mutating residency check (no LRU promotion, no counters).
    pub fn contains(&self, block: u64) -> bool {
        self.map.contains_key(&block)
    }

    /// Drop everything (counters preserved).
    pub fn clear(&mut self) {
        self.map.clear();
        self.nodes.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
    }

    /// Hit rate over the cache's lifetime.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = LruCache::new(2);
        assert!(!c.touch(1));
        assert!(c.touch(1));
        assert!(!c.touch(2));
        assert_eq!(c.len(), 2);
        assert_eq!((c.hits, c.misses), (1, 2));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(2);
        c.touch(1);
        c.touch(2);
        c.touch(1); // 1 is now MRU; LRU is 2
        c.touch(3); // evicts 2
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
    }

    #[test]
    fn zero_capacity_never_hits() {
        let mut c = LruCache::new(0);
        for _ in 0..5 {
            assert!(!c.touch(42));
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn sequential_sweep_larger_than_cache_never_rehits() {
        // the thrash pattern: a cyclic pass over 100 blocks with a 10-block
        // cache re-misses every block on the second pass
        let mut c = LruCache::new(10);
        for _ in 0..2 {
            for b in 0..100 {
                c.touch(b);
            }
        }
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 200);
    }

    #[test]
    fn working_set_within_capacity_all_hits_after_warmup() {
        let mut c = LruCache::new(16);
        for b in 0..16 {
            c.touch(b);
        }
        for _ in 0..10 {
            for b in 0..16 {
                assert!(c.touch(b));
            }
        }
        assert_eq!(c.misses, 16);
        assert_eq!(c.hits, 160);
    }

    #[test]
    fn clear_keeps_counters_drops_content() {
        let mut c = LruCache::new(4);
        c.touch(1);
        c.touch(2);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.misses, 2);
        assert!(!c.touch(1)); // re-miss after clear
    }

    #[test]
    fn slab_reuse_after_eviction_is_consistent() {
        let mut c = LruCache::new(3);
        for b in 0..100u64 {
            c.touch(b);
            // the three most recent must always be resident
            if b >= 2 {
                assert!(c.contains(b) && c.contains(b - 1) && c.contains(b - 2));
            }
            assert!(c.len() <= 3);
        }
    }

    #[test]
    fn hit_rate() {
        let mut c = LruCache::new(1);
        assert_eq!(c.hit_rate(), 0.0);
        c.touch(1);
        c.touch(1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }
}
