//! Real `.sxb` file reader — out-of-core batch source.
//!
//! Where the simulator *models* device time, this reader *performs* the
//! reads, so (a) datasets larger than RAM can be trained on directly, and
//! (b) the real syscall/copy cost of scattered vs contiguous access on this
//! machine can be measured (EXPERIMENTS.md reports both). Labels are tiny
//! (4 bytes/row) and kept resident; feature rows are read per batch.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use crate::data::batch::RowSelection;
use crate::data::dense::HEADER_BYTES;
use crate::error::{Error, Result};

/// Disk-backed feature source over one `.sxb` file.
#[derive(Debug)]
pub struct DiskSource {
    file: File,
    rows: usize,
    cols: usize,
    x_base: u64,
    /// Resident label vector.
    y: Vec<f32>,
    /// Bytes actually read from the file (lifetime).
    pub bytes_read: u64,
    /// Read syscalls issued (lifetime) — the real-IO analogue of "seeks".
    pub read_calls: u64,
}

impl DiskSource {
    /// Open an `.sxb` file, validating the header and loading labels.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut file = File::open(path)?;
        let mut hdr = [0u8; 24];
        file.read_exact(&mut hdr)?;
        if &hdr[0..4] != b"SXB1" {
            return Err(Error::DatasetParse { line: 0, msg: "bad .sxb magic".into() });
        }
        let rows = u64::from_le_bytes(hdr[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(hdr[16..24].try_into().unwrap()) as usize;
        if rows == 0 || cols == 0 {
            return Err(Error::DatasetParse { line: 0, msg: "bad .sxb dims".into() });
        }
        let mut yraw = vec![0u8; rows * 4];
        file.read_exact(&mut yraw)?;
        let y = yraw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(DiskSource {
            file,
            rows,
            cols,
            x_base: HEADER_BYTES + rows as u64 * 4,
            y,
            bytes_read: 0,
            read_calls: 0,
        })
    }

    /// Number of data points.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Feature dimension.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident labels.
    pub fn labels(&self) -> &[f32] {
        &self.y
    }

    /// Read the selected feature rows into `x_out` (cleared first) and the
    /// matching labels into `y_out`. Contiguous selections issue **one**
    /// read; scattered selections issue one seek+read per row — the physical
    /// difference the paper exploits.
    pub fn read_selection(
        &mut self,
        sel: &RowSelection,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<f32>,
    ) -> Result<()> {
        let row_bytes = self.cols * 4;
        x_out.clear();
        y_out.clear();
        match sel {
            RowSelection::Contiguous { start, end } => {
                if *end > self.rows || start >= end {
                    return Err(Error::Other(format!(
                        "selection [{start},{end}) out of bounds ({} rows)",
                        self.rows
                    )));
                }
                let nrows = end - start;
                let mut raw = vec![0u8; nrows * row_bytes];
                self.file
                    .seek(SeekFrom::Start(self.x_base + (*start * row_bytes) as u64))?;
                self.file.read_exact(&mut raw)?;
                self.read_calls += 1;
                self.bytes_read += raw.len() as u64;
                push_f32s(&raw, x_out);
                y_out.extend_from_slice(&self.y[*start..*end]);
            }
            RowSelection::Scattered(rows) => {
                let mut raw = vec![0u8; row_bytes];
                for &r in rows {
                    let r = r as usize;
                    if r >= self.rows {
                        return Err(Error::Other(format!("row {r} out of bounds")));
                    }
                    self.file
                        .seek(SeekFrom::Start(self.x_base + (r * row_bytes) as u64))?;
                    self.file.read_exact(&mut raw)?;
                    self.read_calls += 1;
                    self.bytes_read += raw.len() as u64;
                    push_f32s(&raw, x_out);
                    y_out.push(self.y[r]);
                }
            }
        }
        Ok(())
    }
}

fn push_f32s(raw: &[u8], out: &mut Vec<f32>) {
    out.reserve(raw.len() / 4);
    for c in raw.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseDataset;

    fn setup() -> (std::path::PathBuf, DenseDataset) {
        let x: Vec<f32> = (0..60).map(|v| v as f32).collect(); // 20 rows x 3
        let y: Vec<f32> = (0..20).map(|r| if r % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let ds = DenseDataset::new("t", 3, x, y).unwrap();
        let p = std::env::temp_dir().join(format!("reader_test_{}.sxb", std::process::id()));
        ds.save(&p).unwrap();
        (p, ds)
    }

    #[test]
    fn open_reads_header_and_labels() {
        let (p, ds) = setup();
        let src = DiskSource::open(&p).unwrap();
        assert_eq!(src.rows(), 20);
        assert_eq!(src.cols(), 3);
        assert_eq!(src.labels(), ds.y());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn contiguous_read_matches_memory_one_syscall() {
        let (p, ds) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        src.read_selection(&RowSelection::Contiguous { start: 5, end: 9 }, &mut x, &mut y)
            .unwrap();
        let (want_x, want_y) = ds.rows_slice(5, 9);
        assert_eq!(x, want_x);
        assert_eq!(y, want_y);
        assert_eq!(src.read_calls, 1);
        assert_eq!(src.bytes_read, 4 * 3 * 4);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scattered_read_matches_memory_per_row_syscalls() {
        let (p, ds) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        src.read_selection(&RowSelection::Scattered(vec![19, 0, 7]), &mut x, &mut y)
            .unwrap();
        assert_eq!(&x[0..3], ds.row(19));
        assert_eq!(&x[3..6], ds.row(0));
        assert_eq!(&x[6..9], ds.row(7));
        assert_eq!(y, vec![ds.y()[19], ds.y()[0], ds.y()[7]]);
        assert_eq!(src.read_calls, 3);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn out_of_bounds_selection_errors() {
        let (p, _) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        assert!(src
            .read_selection(&RowSelection::Contiguous { start: 10, end: 25 }, &mut x, &mut y)
            .is_err());
        assert!(src
            .read_selection(&RowSelection::Scattered(vec![20]), &mut x, &mut y)
            .is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_non_sxb_file() {
        let p = std::env::temp_dir().join(format!("reader_bad_{}.sxb", std::process::id()));
        std::fs::write(&p, b"not an sxb file at all........").unwrap();
        assert!(DiskSource::open(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
