//! Real `.sxb` file reader — out-of-core batch source.
//!
//! Where the simulator *models* device time, this reader *performs* the
//! reads, so (a) datasets larger than RAM can be trained on directly, and
//! (b) the real syscall/copy cost of scattered vs contiguous access on this
//! machine can be measured (EXPERIMENTS.md reports both). Labels are tiny
//! (4 bytes/row) and kept resident; feature rows are read per batch.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use crate::data::batch::RowSelection;
use crate::data::dense::HEADER_BYTES;
use crate::error::{Error, Result};

/// Disk-backed feature source over one `.sxb` file.
#[derive(Debug)]
pub struct DiskSource {
    file: File,
    rows: usize,
    cols: usize,
    x_base: u64,
    /// Resident label vector.
    y: Vec<f32>,
    /// Bytes actually read from the file (lifetime).
    pub bytes_read: u64,
    /// Read syscalls issued (lifetime) — the real-IO analogue of "seeks".
    pub read_calls: u64,
}

impl DiskSource {
    /// Open an `.sxb` file, validating the header (magic, dims, and the
    /// claimed geometry against the actual file length, with checked
    /// arithmetic) and loading labels. Every corruption mode — bad magic,
    /// truncated header, lying dims, truncated body — yields a typed
    /// [`Error::Corrupt`] carrying the byte offset where the inconsistency
    /// was detected.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let pstr = path.as_ref().display().to_string();
        let corrupt = |offset: u64, msg: String| Error::Corrupt { path: pstr.clone(), offset, msg };
        let mut file = File::open(path.as_ref())?;
        let file_len = file.metadata()?.len();
        let mut hdr = [0u8; 24];
        file.read_exact(&mut hdr)
            .map_err(|e| corrupt(0, format!("file shorter than the 24-byte header: {e}")))?;
        if &hdr[0..4] != b"SXB1" {
            return Err(corrupt(0, format!("bad .sxb magic {:?}", &hdr[0..4])));
        }
        let rows64 = super::le_u64(&hdr, 8);
        let cols64 = super::le_u64(&hdr, 16);
        if rows64 == 0 || cols64 == 0 {
            return Err(corrupt(8, format!("bad .sxb dims {rows64} x {cols64}")));
        }
        // validate the claimed geometry against the real file length BEFORE
        // allocating anything — a lying header must fail typed, never OOM
        let expected = (|| {
            let labels = 4u64.checked_mul(rows64)?;
            let feats = 4u64.checked_mul(rows64.checked_mul(cols64)?)?;
            HEADER_BYTES.checked_add(labels)?.checked_add(feats)
        })();
        if expected != Some(file_len) {
            return Err(corrupt(
                file_len.min(expected.unwrap_or(u64::MAX)),
                format!(
                    ".sxb length mismatch: header {rows64} x {cols64} expects \
                     {expected:?} bytes, file has {file_len}"
                ),
            ));
        }
        let rows = rows64 as usize;
        let cols = cols64 as usize;
        let mut yraw = vec![0u8; rows * 4];
        file.read_exact(&mut yraw)
            .map_err(|e| corrupt(HEADER_BYTES, format!("truncated label block: {e}")))?;
        let y = yraw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(DiskSource {
            file,
            rows,
            cols,
            x_base: HEADER_BYTES + rows as u64 * 4,
            y,
            bytes_read: 0,
            read_calls: 0,
        })
    }

    /// Number of data points.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Feature dimension.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident labels.
    pub fn labels(&self) -> &[f32] {
        &self.y
    }

    /// Read the selected feature rows into `x_out` (cleared first) and the
    /// matching labels into `y_out`. Contiguous selections issue **one**
    /// read; scattered selections issue one seek+read per row — the physical
    /// difference the paper exploits.
    pub fn read_selection(
        &mut self,
        sel: &RowSelection,
        x_out: &mut Vec<f32>,
        y_out: &mut Vec<f32>,
    ) -> Result<()> {
        let row_bytes = self.cols * 4;
        x_out.clear();
        y_out.clear();
        match sel {
            RowSelection::Contiguous { start, end } => {
                if *end > self.rows || start >= end {
                    return Err(Error::Other(format!(
                        "selection [{start},{end}) out of bounds ({} rows)",
                        self.rows
                    )));
                }
                let nrows = end - start;
                let mut raw = vec![0u8; nrows * row_bytes];
                self.file
                    .seek(SeekFrom::Start(self.x_base + (*start * row_bytes) as u64))?;
                self.file.read_exact(&mut raw)?;
                self.read_calls += 1;
                self.bytes_read += raw.len() as u64;
                push_f32s(&raw, x_out);
                y_out.extend_from_slice(&self.y[*start..*end]);
            }
            RowSelection::Scattered(rows) => {
                let mut raw = vec![0u8; row_bytes];
                for &r in rows {
                    let r = r as usize;
                    if r >= self.rows {
                        return Err(Error::Other(format!("row {r} out of bounds")));
                    }
                    self.file
                        .seek(SeekFrom::Start(self.x_base + (r * row_bytes) as u64))?;
                    self.file.read_exact(&mut raw)?;
                    self.read_calls += 1;
                    self.bytes_read += raw.len() as u64;
                    push_f32s(&raw, x_out);
                    y_out.push(self.y[r]);
                }
            }
        }
        Ok(())
    }
}

fn push_f32s(raw: &[u8], out: &mut Vec<f32>) {
    out.reserve(raw.len() / 4);
    for c in raw.chunks_exact(4) {
        out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense::DenseDataset;

    fn setup() -> (std::path::PathBuf, DenseDataset) {
        let x: Vec<f32> = (0..60).map(|v| v as f32).collect(); // 20 rows x 3
        let y: Vec<f32> = (0..20).map(|r| if r % 3 == 0 { 1.0 } else { -1.0 }).collect();
        let ds = DenseDataset::new("t", 3, x, y).unwrap();
        let p = std::env::temp_dir().join(format!("reader_test_{}.sxb", std::process::id()));
        ds.save(&p).unwrap();
        (p, ds)
    }

    #[test]
    fn open_reads_header_and_labels() {
        let (p, ds) = setup();
        let src = DiskSource::open(&p).unwrap();
        assert_eq!(src.rows(), 20);
        assert_eq!(src.cols(), 3);
        assert_eq!(src.labels(), ds.y());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn contiguous_read_matches_memory_one_syscall() {
        let (p, ds) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        src.read_selection(&RowSelection::Contiguous { start: 5, end: 9 }, &mut x, &mut y)
            .unwrap();
        let (want_x, want_y) = ds.rows_slice(5, 9);
        assert_eq!(x, want_x);
        assert_eq!(y, want_y);
        assert_eq!(src.read_calls, 1);
        assert_eq!(src.bytes_read, 4 * 3 * 4);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scattered_read_matches_memory_per_row_syscalls() {
        let (p, ds) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        src.read_selection(&RowSelection::Scattered(vec![19, 0, 7]), &mut x, &mut y)
            .unwrap();
        assert_eq!(&x[0..3], ds.row(19));
        assert_eq!(&x[3..6], ds.row(0));
        assert_eq!(&x[6..9], ds.row(7));
        assert_eq!(y, vec![ds.y()[19], ds.y()[0], ds.y()[7]]);
        assert_eq!(src.read_calls, 3);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn out_of_bounds_selection_errors() {
        let (p, _) = setup();
        let mut src = DiskSource::open(&p).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        assert!(src
            .read_selection(&RowSelection::Contiguous { start: 10, end: 25 }, &mut x, &mut y)
            .is_err());
        assert!(src
            .read_selection(&RowSelection::Scattered(vec![20]), &mut x, &mut y)
            .is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_non_sxb_file() {
        let p = std::env::temp_dir().join(format!("reader_bad_{}.sxb", std::process::id()));
        std::fs::write(&p, b"not an sxb file at all........").unwrap();
        match DiskSource::open(&p) {
            Err(Error::Corrupt { offset: 0, msg, .. }) => assert!(msg.contains("magic"), "{msg}"),
            other => panic!("expected Corrupt at offset 0, got {other:?}"),
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_modes_yield_typed_errors_with_offsets() {
        // build a real, valid file, then corrupt it in place four ways
        let (p, _) = setup();
        let valid = std::fs::read(&p).unwrap();

        // (1) truncated mid-body: length check fires at the end of the file
        let truncated = &valid[..valid.len() - 10];
        std::fs::write(&p, truncated).unwrap();
        match DiskSource::open(&p) {
            Err(Error::Corrupt { offset, msg, .. }) => {
                assert_eq!(offset, truncated.len() as u64, "offset = valid prefix end");
                assert!(msg.contains("length mismatch"), "{msg}");
            }
            other => panic!("expected Corrupt for truncation, got {other:?}"),
        }

        // (2) flipped magic byte
        let mut bad_magic = valid.clone();
        bad_magic[1] ^= 0xFF;
        std::fs::write(&p, &bad_magic).unwrap();
        match DiskSource::open(&p) {
            Err(Error::Corrupt { offset: 0, .. }) => {}
            other => panic!("expected Corrupt at 0, got {other:?}"),
        }

        // (3) header lies about rows: length mismatch, detected without
        // allocating the claimed geometry
        let mut lying = valid.clone();
        lying[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&p, &lying).unwrap();
        match DiskSource::open(&p) {
            Err(Error::Corrupt { msg, .. }) => assert!(msg.contains("length mismatch"), "{msg}"),
            other => panic!("expected Corrupt for lying header, got {other:?}"),
        }

        // (4) zero dims
        let mut zeroed = valid.clone();
        zeroed[8..16].copy_from_slice(&0u64.to_le_bytes());
        std::fs::write(&p, &zeroed).unwrap();
        match DiskSource::open(&p) {
            Err(Error::Corrupt { offset: 8, msg, .. }) => assert!(msg.contains("dims"), "{msg}"),
            other => panic!("expected Corrupt at 8, got {other:?}"),
        }

        // restore and confirm the file still opens (the corruption was ours)
        std::fs::write(&p, &valid).unwrap();
        assert!(DiskSource::open(&p).is_ok());
        std::fs::remove_file(p).ok();
    }
}
