//! Mini-batch views and the gather/borrow assembler.
//!
//! The assembler is where the paper's effect shows up *for real* (not just in
//! the simulator): contiguous selections (CS/SS) borrow the dataset slice
//! zero-copy, while scattered selections (RS) must gather row-by-row into a
//! scratch buffer — extra memory traffic on every iteration.

use crate::data::dense::DenseDataset;

/// Which rows a mini-batch selects. Produced by `sampling::Sampler`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RowSelection {
    /// Rows `[start, end)` — contiguous in memory and on disk.
    Contiguous { start: usize, end: usize },
    /// Explicit row list (random sampling); may contain duplicates for
    /// RS-with-replacement.
    Scattered(Vec<u32>),
}

impl RowSelection {
    /// Number of selected rows.
    pub fn len(&self) -> usize {
        match self {
            RowSelection::Contiguous { start, end } => end - start,
            RowSelection::Scattered(v) => v.len(),
        }
    }

    /// True when no rows are selected.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Iterate the selected row indices in order.
    pub fn iter(&self) -> Box<dyn Iterator<Item = usize> + '_> {
        match self {
            RowSelection::Contiguous { start, end } => Box::new(*start..*end),
            RowSelection::Scattered(v) => Box::new(v.iter().map(|&i| i as usize)),
        }
    }

    /// True if this selection is a single contiguous run.
    pub fn is_contiguous(&self) -> bool {
        matches!(self, RowSelection::Contiguous { .. })
    }
}

/// A borrowed, assembled mini-batch ready for a compute backend.
#[derive(Debug, Clone, Copy)]
pub struct BatchView<'a> {
    /// Row-major features, `rows * cols`.
    pub x: &'a [f32],
    /// Labels, length `rows`.
    pub y: &'a [f32],
    /// Real (un-padded) row count.
    pub rows: usize,
    /// Feature dimension.
    pub cols: usize,
}

/// Gather `sel` from `ds` into fresh owned buffers, regardless of whether
/// the selection is contiguous.
///
/// This is the *copying* path: the prefetch reader uses it for scattered
/// (RS) selections, and the property tests use it to force an owned copy of
/// a contiguous selection so the zero-copy `Borrowed` payload can be checked
/// bit-for-bit against a materialized gather.
pub fn gather_owned(ds: &DenseDataset, sel: &RowSelection) -> (Vec<f32>, Vec<f32>) {
    let cols = ds.cols();
    let rows = sel.len();
    let mut x = Vec::with_capacity(rows * cols);
    let mut y = Vec::with_capacity(rows);
    match sel {
        RowSelection::Contiguous { start, end } => {
            let (xs, ys) = ds.rows_slice(*start, *end);
            x.extend_from_slice(xs);
            y.extend_from_slice(ys);
        }
        RowSelection::Scattered(idx) => {
            for &r in idx {
                let r = r as usize;
                x.extend_from_slice(ds.row(r));
                y.push(ds.y()[r]);
            }
        }
    }
    (x, y)
}

/// Reusable gather buffer: assembles a [`BatchView`] from a [`RowSelection`],
/// borrowing the dataset directly when the selection is contiguous.
#[derive(Debug, Default)]
pub struct BatchAssembler {
    x_buf: Vec<f32>,
    y_buf: Vec<f32>,
    /// Number of rows gathered (copied) since construction — a real,
    /// measured component of access cost reported by the metrics.
    pub gathered_rows: u64,
    /// Number of zero-copy (borrowed) batches served.
    pub borrowed_batches: u64,
}

impl BatchAssembler {
    /// New assembler; buffers grow on first gather.
    pub fn new() -> Self {
        Self::default()
    }

    /// Assemble `sel` from `ds`. Contiguous selections are zero-copy.
    pub fn assemble<'a>(&'a mut self, ds: &'a DenseDataset, sel: &RowSelection) -> BatchView<'a> {
        let cols = ds.cols();
        match sel {
            RowSelection::Contiguous { start, end } => {
                self.borrowed_batches += 1;
                let (x, y) = ds.rows_slice(*start, *end);
                BatchView { x, y, rows: end - start, cols }
            }
            RowSelection::Scattered(idx) => {
                let rows = idx.len();
                self.x_buf.clear();
                self.x_buf.reserve(rows * cols);
                self.y_buf.clear();
                self.y_buf.reserve(rows);
                for &r in idx {
                    let r = r as usize;
                    self.x_buf.extend_from_slice(ds.row(r));
                    self.y_buf.push(ds.y()[r]);
                }
                self.gathered_rows += rows as u64;
                BatchView { x: &self.x_buf, y: &self.y_buf, rows, cols }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> DenseDataset {
        let x: Vec<f32> = (0..20).map(|v| v as f32).collect(); // 10 rows x 2
        let y: Vec<f32> = (0..10).map(|r| if r % 2 == 0 { 1.0 } else { -1.0 }).collect();
        DenseDataset::new("t", 2, x, y).unwrap()
    }

    #[test]
    fn selection_len_and_iter() {
        let c = RowSelection::Contiguous { start: 2, end: 5 };
        assert_eq!(c.len(), 3);
        assert!(!c.is_empty());
        assert_eq!(c.iter().collect::<Vec<_>>(), vec![2, 3, 4]);
        let s = RowSelection::Scattered(vec![7, 1, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![7, 1, 7]);
        assert!(!s.is_contiguous());
        assert!(c.is_contiguous());
    }

    #[test]
    fn contiguous_assembly_is_zero_copy() {
        let d = ds();
        let mut asm = BatchAssembler::new();
        let sel = RowSelection::Contiguous { start: 3, end: 6 };
        let v = asm.assemble(&d, &sel);
        assert_eq!(v.rows, 3);
        assert_eq!(v.x.as_ptr(), d.row(3).as_ptr(), "must borrow, not copy");
        assert_eq!(v.y, &d.y()[3..6]);
        assert_eq!(asm.gathered_rows, 0);
        assert_eq!(asm.borrowed_batches, 1);
    }

    #[test]
    fn scattered_assembly_gathers_in_order() {
        let d = ds();
        let mut asm = BatchAssembler::new();
        let sel = RowSelection::Scattered(vec![9, 0, 4]);
        let v = asm.assemble(&d, &sel);
        assert_eq!(v.rows, 3);
        assert_eq!(v.x, &[18.0, 19.0, 0.0, 1.0, 8.0, 9.0]);
        assert_eq!(v.y, &[-1.0, 1.0, 1.0]);
        assert_eq!(asm.gathered_rows, 3);
    }

    #[test]
    fn gather_owned_copies_contiguous_and_scattered_identically() {
        let d = ds();
        let (cx, cy) = gather_owned(&d, &RowSelection::Contiguous { start: 3, end: 6 });
        let (want_x, want_y) = d.rows_slice(3, 6);
        assert_eq!(cx, want_x);
        assert_eq!(cy, want_y);
        assert_ne!(cx.as_ptr(), d.row(3).as_ptr(), "gather_owned must copy");
        let (sx, sy) = gather_owned(&d, &RowSelection::Scattered(vec![9, 0, 4]));
        assert_eq!(sx, &[18.0, 19.0, 0.0, 1.0, 8.0, 9.0]);
        assert_eq!(sy, &[-1.0, 1.0, 1.0]);
    }

    #[test]
    fn with_replacement_duplicates_are_gathered() {
        let d = ds();
        let mut asm = BatchAssembler::new();
        let v = asm.assemble(&d, &RowSelection::Scattered(vec![2, 2]));
        assert_eq!(v.x, &[4.0, 5.0, 4.0, 5.0]);
    }

    #[test]
    fn assembler_buffer_reuse_across_batches() {
        let d = ds();
        let mut asm = BatchAssembler::new();
        for _ in 0..5 {
            let v = asm.assemble(&d, &RowSelection::Scattered(vec![1, 2, 3]));
            assert_eq!(v.rows, 3);
        }
        assert_eq!(asm.gathered_rows, 15);
    }
}
