//! Datasets: the layout seam of the whole system.
//!
//! Two concrete stores live behind one [`Dataset`] type:
//!
//! * [`DenseDataset`] — row-major `f32` features (`.sxb` on disk). Chosen
//!   for the paper's low-dimensional physics sets (HIGGS, SUSY, covtype…)
//!   where nearly every entry is populated.
//! * [`CsrDataset`] — compressed sparse rows (`values`/`col_idx`/`row_ptr`,
//!   `.sxc` on disk). Chosen for high-dimensional LIBSVM ingests (rcv1,
//!   news20) and sparse synthetics, where densifying is impossible — O(nnz)
//!   memory, nnz-proportional access cost.
//!
//! Everything downstream (samplers, the storage simulator, the zero-copy
//! prefetch pipeline, the solvers) is layout-polymorphic through
//! [`batch::BatchView`]; only the innermost math kernels dispatch on the
//! layout. Contiguous CS/SS selections borrow either layout zero-copy — a
//! dense row range is one slice, a CSR row range is three.

pub mod batch;
pub mod csr;
pub mod dense;
pub mod libsvm;
pub mod registry;
pub mod scaling;
pub mod synth;

pub use batch::{BatchAssembler, BatchView, OwnedBatch};
pub use csr::CsrDataset;
pub use dense::DenseDataset;

use crate::data::batch::RowSelection;

/// A dataset in one of the two supported memory layouts.
#[derive(Debug, Clone)]
pub enum Dataset {
    /// Dense row-major store.
    Dense(DenseDataset),
    /// Compressed-sparse-row store.
    Csr(CsrDataset),
}

impl From<DenseDataset> for Dataset {
    fn from(d: DenseDataset) -> Self {
        Dataset::Dense(d)
    }
}

impl From<CsrDataset> for Dataset {
    fn from(c: CsrDataset) -> Self {
        Dataset::Csr(c)
    }
}

impl Dataset {
    /// Dataset name.
    pub fn name(&self) -> &str {
        match self {
            Dataset::Dense(d) => &d.name,
            Dataset::Csr(c) => &c.name,
        }
    }

    /// Number of data points `l`.
    #[inline]
    pub fn rows(&self) -> usize {
        match self {
            Dataset::Dense(d) => d.rows(),
            Dataset::Csr(c) => c.rows(),
        }
    }

    /// Feature dimension `n`.
    #[inline]
    pub fn cols(&self) -> usize {
        match self {
            Dataset::Dense(d) => d.cols(),
            Dataset::Csr(c) => c.cols(),
        }
    }

    /// Stored entries: `rows * cols` for dense, the non-zero count for CSR.
    #[inline]
    pub fn nnz(&self) -> usize {
        match self {
            Dataset::Dense(d) => d.rows() * d.cols(),
            Dataset::Csr(c) => c.nnz(),
        }
    }

    /// Full label vector.
    #[inline]
    pub fn y(&self) -> &[f32] {
        match self {
            Dataset::Dense(d) => d.y(),
            Dataset::Csr(c) => c.y(),
        }
    }

    /// True for the CSR layout.
    pub fn is_csr(&self) -> bool {
        matches!(self, Dataset::Csr(_))
    }

    /// The dense store, if this is a dense dataset.
    pub fn as_dense(&self) -> Option<&DenseDataset> {
        match self {
            Dataset::Dense(d) => Some(d),
            Dataset::Csr(_) => None,
        }
    }

    /// The CSR store, if this is a CSR dataset.
    pub fn as_csr(&self) -> Option<&CsrDataset> {
        match self {
            Dataset::Csr(c) => Some(c),
            Dataset::Dense(_) => None,
        }
    }

    /// Zero-copy [`BatchView`] of contiguous rows `[start, end)` — the CS/SS
    /// fast path for both layouts.
    #[inline]
    pub fn slice_view(&self, start: usize, end: usize) -> BatchView<'_> {
        match self {
            Dataset::Dense(d) => {
                let (x, y) = d.rows_slice(start, end);
                BatchView::dense(x, y, d.cols())
            }
            Dataset::Csr(c) => BatchView::Csr(c.slice(start, end)),
        }
    }

    /// Feature (+ index, for CSR) bytes a selection spans — what a borrow
    /// serves zero-copy or a gather must copy. Duplicated scattered rows are
    /// counted each time (they are gathered each time).
    pub fn payload_bytes(&self, sel: &RowSelection) -> u64 {
        match self {
            Dataset::Dense(d) => sel.len() as u64 * d.cols() as u64 * 4,
            Dataset::Csr(c) => match sel {
                RowSelection::Contiguous { start, end } => c.payload_bytes(*start, *end),
                RowSelection::Scattered(rows) => rows
                    .iter()
                    .map(|&r| c.row_nnz(r as usize) as u64 * csr::NNZ_BYTES)
                    .sum(),
            },
        }
    }

    /// Upper bound on the per-sample gradient Lipschitz constant
    /// (`max_i ||x_i||^2 / 4 + C`) — O(stored entries).
    pub fn lipschitz(&self, c: f32) -> f64 {
        match self {
            Dataset::Dense(d) => d.lipschitz(c),
            Dataset::Csr(s) => s.lipschitz(c),
        }
    }

    /// Total size of the on-disk encoding (`.sxb` / `.sxc`) in bytes.
    pub fn file_bytes(&self) -> u64 {
        match self {
            Dataset::Dense(d) => d.file_bytes(),
            Dataset::Csr(c) => c.file_bytes(),
        }
    }

    /// One-time random row permutation (paper §5 pre-shuffle), layout
    /// preserving.
    pub fn shuffle_rows(&mut self, seed: u64) {
        match self {
            Dataset::Dense(d) => scaling::shuffle_rows(d, seed),
            Dataset::Csr(c) => c.shuffle_rows(seed),
        }
    }

    /// Save to the layout's native binary format.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> crate::error::Result<()> {
        match self {
            Dataset::Dense(d) => d.save(path),
            Dataset::Csr(c) => c.save(path),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense() -> Dataset {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        Dataset::Dense(DenseDataset::new("d", 3, x, vec![1.0, -1.0, 1.0, -1.0]).unwrap())
    }

    fn csr() -> Dataset {
        Dataset::Csr(
            CsrDataset::new(
                "c",
                100,
                vec![1.0, 2.0, 3.0],
                vec![5, 50, 99],
                vec![0, 2, 2, 3],
                vec![1.0, -1.0, 1.0],
            )
            .unwrap(),
        )
    }

    #[test]
    fn shared_accessors_dispatch() {
        let d = dense();
        assert_eq!((d.rows(), d.cols(), d.nnz()), (4, 3, 12));
        assert!(!d.is_csr());
        assert!(d.as_dense().is_some() && d.as_csr().is_none());
        let c = csr();
        assert_eq!((c.rows(), c.cols(), c.nnz()), (3, 100, 3));
        assert!(c.is_csr());
        assert_eq!(c.name(), "c");
        assert!(c.lipschitz(0.0) > 0.0);
    }

    #[test]
    fn payload_bytes_by_layout() {
        let d = dense();
        assert_eq!(d.payload_bytes(&RowSelection::Contiguous { start: 0, end: 2 }), 24);
        assert_eq!(d.payload_bytes(&RowSelection::Scattered(vec![0, 0])), 24);
        let c = csr();
        // rows 0..2: 2 nnz -> 16 bytes (values + indices); row 1 is empty
        assert_eq!(c.payload_bytes(&RowSelection::Contiguous { start: 0, end: 2 }), 16);
        assert_eq!(c.payload_bytes(&RowSelection::Scattered(vec![2, 1, 2])), 16);
    }

    #[test]
    fn slice_view_matches_layout() {
        assert!(dense().slice_view(0, 2).as_dense().is_some());
        assert!(csr().slice_view(0, 2).as_csr().is_some());
        assert_eq!(csr().slice_view(1, 3).rows(), 2);
    }
}
