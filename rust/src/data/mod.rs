//! Datasets: in-memory dense store, on-disk binary layout, LIBSVM ingestion,
//! synthetic stand-ins for the paper's eight benchmark datasets, and the
//! dataset registry that maps names to generation profiles.

pub mod batch;
pub mod dense;
pub mod libsvm;
pub mod registry;
pub mod scaling;
pub mod synth;

pub use batch::{BatchAssembler, BatchView};
pub use dense::DenseDataset;
