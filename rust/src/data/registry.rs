//! Dataset registry: the paper's eight benchmarks as scaled synthetic
//! profiles (Table 1 → DESIGN.md §3), plus lookup of real LIBSVM files.
//!
//! Feature dims here MUST stay in sync with `python/compile/aot.py`
//! (`FEATURE_DIMS`) — the AOT grid lowers one set of modules per dim.

use std::path::Path;

use crate::data::dense::DenseDataset;
use crate::data::libsvm::{self, LabelMap};
use crate::data::synth::{self, FeatureDist, SynthSpec};
use crate::error::{Error, Result};

/// One registry entry: scaled profile + pointer to the real dataset.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub spec: SynthSpec,
    /// Original (paper, Table 1): rows, features — for documentation and
    /// scale-factor reporting.
    pub paper_rows: usize,
    pub paper_cols: usize,
    /// LIBSVM file name to prefer when present under the data dir.
    pub libsvm_file: &'static str,
    pub label_map: LabelMap,
    /// Regularization coefficient used by the experiments.
    pub reg_c: f32,
}

/// All eight profiles (paper Table 1, scaled — DESIGN.md §3).
pub fn profiles() -> Vec<DatasetProfile> {
    vec![
        DatasetProfile {
            spec: SynthSpec {
                name: "higgs-mini",
                rows: 120_000,
                cols: 28,
                dist: FeatureDist::Gaussian,
                flip_prob: 0.12,
                margin_noise: 1.2,
                pos_fraction: 0.53,
            },
            paper_rows: 11_000_000,
            paper_cols: 28,
            libsvm_file: "HIGGS",
            label_map: LabelMap::Binary,
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "susy-mini",
                rows: 100_000,
                cols: 18,
                dist: FeatureDist::Gaussian,
                flip_prob: 0.10,
                margin_noise: 1.0,
                pos_fraction: 0.46,
            },
            paper_rows: 5_000_000,
            paper_cols: 18,
            libsvm_file: "SUSY",
            label_map: LabelMap::Binary,
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "sensit-mini",
                rows: 40_000,
                cols: 100,
                dist: FeatureDist::Correlated { rank: 12 },
                flip_prob: 0.08,
                margin_noise: 0.8,
                pos_fraction: 0.5,
            },
            paper_rows: 78_823,
            paper_cols: 100,
            libsvm_file: "combined",
            label_map: LabelMap::OneVsRest(3),
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "mnist-mini",
                rows: 20_000,
                cols: 256,
                dist: FeatureDist::SparseUniform { density: 0.25 },
                flip_prob: 0.02,
                margin_noise: 0.3,
                pos_fraction: 0.49,
            },
            paper_rows: 60_000,
            paper_cols: 780,
            libsvm_file: "mnist",
            label_map: LabelMap::OddEven,
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "protein-mini",
                rows: 18_000,
                cols: 128,
                dist: FeatureDist::Correlated { rank: 16 },
                flip_prob: 0.15,
                margin_noise: 1.0,
                pos_fraction: 0.45,
            },
            paper_rows: 17_766,
            paper_cols: 357,
            libsvm_file: "protein",
            label_map: LabelMap::OneVsRest(1),
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "rcv1-mini",
                rows: 20_000,
                cols: 512,
                dist: FeatureDist::SparseUniform { density: 0.02 },
                flip_prob: 0.03,
                margin_noise: 0.2,
                pos_fraction: 0.52,
            },
            paper_rows: 20_242,
            paper_cols: 47_236,
            libsvm_file: "rcv1_train.binary",
            label_map: LabelMap::Binary,
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "covtype-mini",
                rows: 80_000,
                cols: 54,
                dist: FeatureDist::SparseUniform { density: 0.4 },
                flip_prob: 0.05,
                margin_noise: 0.5,
                pos_fraction: 0.51,
            },
            paper_rows: 581_012,
            paper_cols: 54,
            libsvm_file: "covtype.libsvm.binary",
            label_map: LabelMap::Binary,
            reg_c: 1e-4,
        },
        DatasetProfile {
            spec: SynthSpec {
                name: "ijcnn1-mini",
                rows: 50_000,
                cols: 22,
                dist: FeatureDist::Gaussian,
                flip_prob: 0.07,
                margin_noise: 0.7,
                pos_fraction: 0.10,
            },
            paper_rows: 49_990,
            paper_cols: 22,
            libsvm_file: "ijcnn1",
            label_map: LabelMap::Binary,
            reg_c: 1e-4,
        },
    ]
}

/// Names of every registered dataset.
pub fn names() -> Vec<&'static str> {
    profiles().iter().map(|p| p.spec.name).collect()
}

/// Look a profile up by name.
pub fn profile(name: &str) -> Result<DatasetProfile> {
    profiles()
        .into_iter()
        .find(|p| p.spec.name == name)
        .ok_or_else(|| Error::Config(format!("unknown dataset '{name}' (known: {:?})", names())))
}

/// Generate the synthetic stand-in for `name`.
pub fn generate(name: &str, seed: u64) -> Result<DenseDataset> {
    let p = profile(name)?;
    synth::generate(&p.spec, seed)
}

/// Resolve a dataset: prefer `<data_dir>/<name>.sxb`, then the real LIBSVM
/// file, then generate the synthetic stand-in (and cache it as `.sxb`).
pub fn resolve(name: &str, data_dir: impl AsRef<Path>, seed: u64) -> Result<DenseDataset> {
    let p = profile(name)?;
    let dir = data_dir.as_ref();
    let sxb = dir.join(format!("{name}.sxb"));
    if sxb.is_file() {
        return DenseDataset::load(&sxb);
    }
    let raw = dir.join(p.libsvm_file);
    if raw.is_file() {
        let mut ds = libsvm::parse_libsvm(&raw, Some(p.spec.cols), p.label_map,
                                          Some(p.spec.rows))?;
        crate::data::scaling::standardize(&mut ds);
        return Ok(ds);
    }
    let ds = synth::generate(&p.spec, seed)?;
    if dir.is_dir() {
        ds.save(&sxb).ok(); // cache is best-effort
    }
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_profiles_matching_paper_dims() {
        let ps = profiles();
        assert_eq!(ps.len(), 8);
        // paper Table 1 dims preserved where the stand-in is unscaled
        let by_name = |n: &str| ps.iter().find(|p| p.spec.name == n).unwrap().clone();
        assert_eq!(by_name("higgs-mini").paper_cols, 28);
        assert_eq!(by_name("higgs-mini").spec.cols, 28);
        assert_eq!(by_name("susy-mini").spec.cols, 18);
        assert_eq!(by_name("covtype-mini").spec.cols, 54);
        assert_eq!(by_name("ijcnn1-mini").spec.cols, 22);
    }

    #[test]
    fn dims_match_aot_grid() {
        // python/compile/aot.py FEATURE_DIMS = (18,22,28,54,100,128,256,512)
        let aot_dims = [18, 22, 28, 54, 100, 128, 256, 512];
        for p in profiles() {
            assert!(
                aot_dims.contains(&p.spec.cols),
                "{} dim {} missing from AOT grid",
                p.spec.name,
                p.spec.cols
            );
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(profile("nope").is_err());
        assert!(generate("nope", 0).is_err());
    }

    #[test]
    fn generate_small_profile() {
        // trim a profile to keep the test fast
        let mut p = profile("ijcnn1-mini").unwrap();
        p.spec.rows = 2000;
        let d = synth::generate(&p.spec, 42).unwrap();
        assert_eq!(d.rows(), 2000);
        assert_eq!(d.cols(), 22);
        // ijcnn1 is ~10% positive
        let pos = d.y().iter().filter(|&&v| v > 0.0).count() as f64 / 2000.0;
        assert!(pos < 0.2, "pos={pos}");
    }

    #[test]
    fn resolve_falls_back_to_synth_and_caches() {
        let dir = std::env::temp_dir().join(format!("sx_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // use the smallest profile for speed: protein-mini is 18k rows; use
        // resolve on a generated tiny spec instead via direct generate+save
        let mut p = profile("ijcnn1-mini").unwrap();
        p.spec.rows = 500;
        let d = synth::generate(&p.spec, 1).unwrap();
        d.save(dir.join("ijcnn1-mini.sxb")).unwrap();
        let d2 = resolve("ijcnn1-mini", &dir, 1).unwrap();
        assert_eq!(d2.rows(), 500);
        std::fs::remove_dir_all(&dir).ok();
    }
}
