//! LIBSVM text-format parser.
//!
//! The paper's eight benchmark datasets ship in LIBSVM sparse text format
//! (`label idx:val idx:val ...`, 1-based indices). This parser ingests the
//! *real* files when present under `data/` (HIGGS, SUSY, covtype.binary, …)
//! and densifies into a [`DenseDataset`]; the synthetic registry stand-ins
//! are used otherwise (DESIGN.md §3).
//!
//! Multi-class labels are mapped to binary the same way the paper's
//! experiments require a binary logistic loss:
//! * labels already in {-1,+1} (or {0,1}) pass through;
//! * otherwise classes are split odd/even (mnist) or first-vs-rest.

use std::io::{BufRead, BufReader};
use std::path::Path;

use crate::data::dense::DenseDataset;
use crate::error::{Error, Result};

/// How to binarize multi-class labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LabelMap {
    /// Expect {-1,+1} or {0,1}; error on anything else.
    Binary,
    /// `+1` when `round(label) % 2 == 1` (mnist odd/even convention).
    OddEven,
    /// `+1` when label equals the given class, else `-1`.
    OneVsRest(i32),
}

/// Parse LIBSVM text into a dense dataset.
///
/// * `cols`: densified feature count. Pass `None` to infer the max index
///   (requires a full pre-scan — done in one pass by buffering parsed rows).
/// * `max_rows`: optional row cap (the paper's large sets can be subsampled
///   with a head-prefix, preserving on-disk contiguity).
pub fn parse_libsvm(
    path: impl AsRef<Path>,
    cols: Option<usize>,
    label_map: LabelMap,
    max_rows: Option<usize>,
) -> Result<DenseDataset> {
    let name = path
        .as_ref()
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    let f = std::fs::File::open(path.as_ref())?;
    let reader = BufReader::new(f);

    let mut labels: Vec<f32> = Vec::new();
    let mut rows: Vec<Vec<(u32, f32)>> = Vec::new();
    let mut max_idx = 0u32;

    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(cap) = max_rows {
            if rows.len() >= cap {
                break;
            }
        }
        let mut parts = line.split_ascii_whitespace();
        let raw_label: f64 = parts
            .next()
            .ok_or_else(|| Error::DatasetParse { line: lineno + 1, msg: "empty line".into() })?
            .parse()
            .map_err(|e| Error::DatasetParse { line: lineno + 1, msg: format!("label: {e}") })?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok.split_once(':').ok_or_else(|| Error::DatasetParse {
                line: lineno + 1,
                msg: format!("expected idx:val, got {tok:?}"),
            })?;
            let idx: u32 = i.parse().map_err(|e| Error::DatasetParse {
                line: lineno + 1,
                msg: format!("index: {e}"),
            })?;
            if idx == 0 {
                return Err(Error::DatasetParse {
                    line: lineno + 1,
                    msg: "LIBSVM indices are 1-based; got 0".into(),
                });
            }
            let val: f32 = v.parse().map_err(|e| Error::DatasetParse {
                line: lineno + 1,
                msg: format!("value: {e}"),
            })?;
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(map_label(raw_label, label_map, lineno + 1)?);
        rows.push(feats);
    }

    if rows.is_empty() {
        return Err(Error::DatasetParse { line: 0, msg: "no data rows".into() });
    }
    let cols = cols.unwrap_or(max_idx as usize);
    if cols == 0 {
        return Err(Error::DatasetParse { line: 0, msg: "no features".into() });
    }

    let mut x = vec![0f32; rows.len() * cols];
    for (r, feats) in rows.iter().enumerate() {
        for &(idx, val) in feats {
            let idx = idx as usize;
            if idx >= cols {
                return Err(Error::DatasetParse {
                    line: r + 1,
                    msg: format!("feature index {} exceeds cols {}", idx + 1, cols),
                });
            }
            x[r * cols + idx] = val;
        }
    }
    DenseDataset::new(name, cols, x, labels)
}

fn map_label(raw: f64, map: LabelMap, line: usize) -> Result<f32> {
    match map {
        LabelMap::Binary => {
            if raw == 1.0 || raw == -1.0 {
                Ok(raw as f32)
            } else if raw == 0.0 {
                Ok(-1.0)
            } else if raw == 2.0 {
                // covtype.binary ships with labels {1,2}
                Ok(-1.0)
            } else {
                Err(Error::DatasetParse {
                    line,
                    msg: format!("non-binary label {raw} (use OddEven/OneVsRest)"),
                })
            }
        }
        LabelMap::OddEven => Ok(if (raw.round() as i64).rem_euclid(2) == 1 { 1.0 } else { -1.0 }),
        LabelMap::OneVsRest(cls) => Ok(if raw.round() as i32 == cls { 1.0 } else { -1.0 }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "libsvm_test_{}_{}.txt",
            std::process::id(),
            content.len()
        ));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        p
    }

    #[test]
    fn parses_basic_binary() {
        let p = write_tmp("+1 1:0.5 3:1.5\n-1 2:2.0\n");
        let d = parse_libsvm(&p, None, LabelMap::Binary, None).unwrap();
        assert_eq!((d.rows(), d.cols()), (2, 3));
        assert_eq!(d.row(0), &[0.5, 0.0, 1.5]);
        assert_eq!(d.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(d.y(), &[1.0, -1.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn respects_explicit_cols_and_max_rows() {
        let p = write_tmp("1 1:1\n-1 2:1\n1 1:2\n");
        let d = parse_libsvm(&p, Some(5), LabelMap::Binary, Some(2)).unwrap();
        assert_eq!((d.rows(), d.cols()), (2, 5));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn covtype_style_12_labels() {
        let p = write_tmp("1 1:1\n2 1:1\n");
        let d = parse_libsvm(&p, None, LabelMap::Binary, None).unwrap();
        assert_eq!(d.y(), &[1.0, -1.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn odd_even_for_mnist() {
        let p = write_tmp("7 1:1\n4 1:1\n0 1:1\n");
        let d = parse_libsvm(&p, None, LabelMap::OddEven, None).unwrap();
        assert_eq!(d.y(), &[1.0, -1.0, -1.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn one_vs_rest() {
        let p = write_tmp("3 1:1\n1 1:1\n3 1:1\n");
        let d = parse_libsvm(&p, None, LabelMap::OneVsRest(3), None).unwrap();
        assert_eq!(d.y(), &[1.0, -1.0, 1.0]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_zero_index_and_garbage() {
        let p = write_tmp("+1 0:1\n");
        assert!(parse_libsvm(&p, None, LabelMap::Binary, None).is_err());
        std::fs::remove_file(p).ok();
        let p = write_tmp("+1 1:abc\n");
        assert!(parse_libsvm(&p, None, LabelMap::Binary, None).is_err());
        std::fs::remove_file(p).ok();
        let p = write_tmp("+5 1:1\n");
        assert!(parse_libsvm(&p, None, LabelMap::Binary, None).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let p = write_tmp("# header\n\n+1 1:1\n");
        let d = parse_libsvm(&p, None, LabelMap::Binary, None).unwrap();
        assert_eq!(d.rows(), 1);
        std::fs::remove_file(p).ok();
    }
}
