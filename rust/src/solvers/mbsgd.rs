//! Mini-Batch SGD (paper refs [3, 8, 23]): `w ← w − α g_j(w)`.
//!
//! The simplest solver and the one Theorem 1 is proved for; the paper's
//! convergence analysis (§3) applies verbatim to this implementation.

use crate::backend::{ComputeBackend, FusedStep};
use crate::data::batch::BatchView;
use crate::error::Result;
use crate::solvers::{GradScratch, Solver};

/// MBSGD state: just the iterate.
#[derive(Debug, Clone)]
pub struct Mbsgd {
    w: Vec<f32>,
    scratch: GradScratch,
    c: f32,
}

impl Mbsgd {
    /// `n` features, `m` batches per epoch (unused — kept for uniformity).
    pub fn new(n: usize, _m: usize) -> Self {
        Mbsgd { w: vec![0f32; n], scratch: GradScratch::new(n), c: 0.0 }
    }

    /// Set the regularization coefficient used in gradients.
    pub fn with_reg(mut self, c: f32) -> Self {
        self.c = c;
        self
    }

    /// Regularization setter used by the driver.
    pub fn set_reg(&mut self, c: f32) {
        self.c = c;
    }
}

impl Solver for Mbsgd {
    fn name(&self) -> &'static str {
        "MBSGD"
    }

    fn w(&self) -> &[f32] {
        &self.w
    }

    fn set_reg(&mut self, c: f32) {
        self.c = c;
    }

    fn epoch_start(&mut self, _epoch: usize) {}

    fn step(
        &mut self,
        be: &mut dyn ComputeBackend,
        batch: &BatchView<'_>,
        _j: usize,
        lr: f32,
    ) -> Result<()> {
        if be.fused(FusedStep::Mbsgd { w: &mut self.w, lr }, batch, self.c)? {
            return Ok(());
        }
        be.grad_into(&self.w, batch, self.c, &mut self.scratch.g)?;
        crate::math::axpy(-lr, &self.scratch.g, &mut self.w);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::rng::Rng;

    fn toy(rows: usize, cols: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from(2);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn one_step_equals_manual_update() {
        let (x, y) = toy(16, 4);
        let view = BatchView { x: &x, y: &y, rows: 16, cols: 4 };
        let mut be = NativeBackend::new();
        let mut s = Mbsgd::new(4, 1).with_reg(0.1);
        s.step(&mut be, &view, 0, 0.2).unwrap();
        let mut g = vec![0f32; 4];
        crate::math::grad_into(&[0.0; 4], &x, &y, 4, 0.1, &mut g);
        for k in 0..4 {
            assert!((s.w()[k] + 0.2 * g[k]).abs() < 1e-7);
        }
    }

    #[test]
    fn descends_batch_objective() {
        let (x, y) = toy(64, 6);
        let view = BatchView { x: &x, y: &y, rows: 64, cols: 6 };
        let mut be = NativeBackend::new();
        let mut s = Mbsgd::new(6, 1).with_reg(0.01);
        let o0 = be.batch_obj(s.w(), &view, 0.01).unwrap();
        for _ in 0..20 {
            s.step(&mut be, &view, 0, 0.1).unwrap();
        }
        let o1 = be.batch_obj(s.w(), &view, 0.01).unwrap();
        assert!(o1 < o0 - 1e-3, "o0={o0} o1={o1}");
    }
}
