//! Dense vector primitives (f32 storage, f64 accumulation for reductions).
//!
//! The solver algebra is O(n) per iteration — negligible next to the O(Bn)
//! gradient — but it runs every inner iteration, so these are allocation-free
//! and written to autovectorize.

/// `y += a * x` (8-lane unrolled via chunks_exact so the bounds checks
/// vanish and the loop vectorizes; see EXPERIMENTS.md §Perf).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (ys, xs) in (&mut yc).zip(&mut xc) {
        for k in 0..8 {
            ys[k] += a * xs[k];
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * *xi;
    }
}

/// `x *= a` (8-lane unrolled like [`axpy`]; elementwise, so bit-identical
/// to the naive loop).
#[inline]
pub fn scal(a: f32, x: &mut [f32]) {
    let mut xc = x.chunks_exact_mut(8);
    for xs in &mut xc {
        for k in 0..8 {
            xs[k] *= a;
        }
    }
    for xi in xc.into_remainder() {
        *xi *= a;
    }
}

/// Dot product with f64 accumulator.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0f64;
    for (xi, yi) in x.iter().zip(y) {
        acc += (*xi as f64) * (*yi as f64);
    }
    acc
}

/// Squared Euclidean norm with f64 accumulation.
///
/// Four independent accumulator chains (the f64 serial-dependency
/// argument of [`dot_f32`], at half the width since f64 lanes are twice
/// as wide); the fixed tree-sum keeps results deterministic.
#[inline]
pub fn nrm2_sq(x: &[f32]) -> f64 {
    let mut acc = [0f64; 4];
    let mut xc = x.chunks_exact(4);
    for xs in &mut xc {
        for k in 0..4 {
            acc[k] += (xs[k] as f64) * (xs[k] as f64);
        }
    }
    let mut tail = 0f64;
    for xi in xc.remainder() {
        tail += (*xi as f64) * (*xi as f64);
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + tail
}

/// f32 dot used in the row-major matvec hot loop.
///
/// Strict-IEEE f32 `acc += x*y` is a serial dependency chain the compiler
/// must not reorder, so the naive loop runs at ~1 add per 4 cycles. Eight
/// independent accumulators break the chain (≈4–5× on this hot path — see
/// EXPERIMENTS.md §Perf); the final tree-sum changes association, which is
/// fine at the f32 tolerance the backends are compared under.
#[inline]
pub fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0f32; 8];
    let mut xc = x.chunks_exact(8);
    let mut yc = y.chunks_exact(8);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        for k in 0..8 {
            acc[k] += xs[k] * ys[k];
        }
    }
    let mut tail = 0f32;
    for (xi, yi) in xc.remainder().iter().zip(yc.remainder()) {
        tail += xi * yi;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Four simultaneous dot products against a shared `w`: `w` streams through
/// registers once for four rows, and the four accumulator chains keep the
/// FMA pipes full. Rows must all have length `w.len()`.
#[inline]
pub fn dot4_f32(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], w: &[f32]) -> [f32; 4] {
    let n = w.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    let mut a0 = 0f32;
    let mut a1 = 0f32;
    let mut a2 = 0f32;
    let mut a3 = 0f32;
    let mut b0 = 0f32;
    let mut b1 = 0f32;
    let mut b2 = 0f32;
    let mut b3 = 0f32;
    let mut k = 0;
    while k + 2 <= n {
        let (wk, wk1) = (w[k], w[k + 1]);
        a0 += x0[k] * wk;
        b0 += x0[k + 1] * wk1;
        a1 += x1[k] * wk;
        b1 += x1[k + 1] * wk1;
        a2 += x2[k] * wk;
        b2 += x2[k + 1] * wk1;
        a3 += x3[k] * wk;
        b3 += x3[k + 1] * wk1;
        k += 2;
    }
    if k < n {
        let wk = w[k];
        a0 += x0[k] * wk;
        a1 += x1[k] * wk;
        a2 += x2[k] * wk;
        a3 += x3[k] * wk;
    }
    [a0 + b0, a1 + b1, a2 + b2, a3 + b3]
}

/// Fused rank-4 update `y += c0 x0 + c1 x1 + c2 x2 + c3 x3`: one load+store
/// of `y` per element instead of four (the dominant cost of the per-row
/// axpy at larger feature dims — EXPERIMENTS.md §Perf).
///
/// 8-wide blocks through fixed-size array views, so the five bounds
/// checks hoist to one per block and the inner loop vectorizes (same
/// rationale as [`axpy`]; elementwise, so results are unchanged).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn axpy4(
    c: [f32; 4],
    x0: &[f32],
    x1: &[f32],
    x2: &[f32],
    x3: &[f32],
    y: &mut [f32],
) {
    let n = y.len();
    debug_assert!(x0.len() == n && x1.len() == n && x2.len() == n && x3.len() == n);
    let blocks = n / 8;
    for b in 0..blocks {
        let base = b * 8;
        let ys: &mut [f32; 8] = (&mut y[base..base + 8]).try_into().expect("8-wide block");
        let a0: &[f32; 8] = (&x0[base..base + 8]).try_into().expect("8-wide block");
        let a1: &[f32; 8] = (&x1[base..base + 8]).try_into().expect("8-wide block");
        let a2: &[f32; 8] = (&x2[base..base + 8]).try_into().expect("8-wide block");
        let a3: &[f32; 8] = (&x3[base..base + 8]).try_into().expect("8-wide block");
        for k in 0..8 {
            ys[k] += c[0] * a0[k] + c[1] * a1[k] + c[2] * a2[k] + c[3] * a3[k];
        }
    }
    for k in blocks * 8..n {
        y[k] += c[0] * x0[k] + c[1] * x1[k] + c[2] * x2[k] + c[3] * x3[k];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot4_matches_four_dots() {
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..13).map(|k| (r * 13 + k) as f32 * 0.1).collect())
            .collect();
        let w: Vec<f32> = (0..13).map(|k| (k as f32 - 6.0) * 0.3).collect();
        let got = dot4_f32(&rows[0], &rows[1], &rows[2], &rows[3], &w);
        for r in 0..4 {
            let want = dot_f32(&rows[r], &w);
            assert!((got[r] - want).abs() < 1e-4, "row {r}: {} vs {want}", got[r]);
        }
    }

    #[test]
    fn axpy4_matches_four_axpys() {
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..11).map(|k| (r + k) as f32 * 0.2).collect())
            .collect();
        let c = [0.5f32, -1.0, 2.0, 0.25];
        let mut y1 = vec![1.0f32; 11];
        let mut y2 = y1.clone();
        axpy4(c, &rows[0], &rows[1], &rows[2], &rows[3], &mut y1);
        for r in 0..4 {
            axpy(c[r], &rows[r], &mut y2);
        }
        for k in 0..11 {
            assert!((y1[k] - y2[k]).abs() < 1e-5);
        }
    }

    #[test]
    fn axpy_matches_manual() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn scal_scales() {
        let mut x = [1.0f32, -2.0, 4.0];
        scal(0.5, &mut x);
        assert_eq!(x, [0.5, -1.0, 2.0]);
    }

    #[test]
    fn dot_and_norm() {
        let x = [1.0f32, 2.0, 2.0];
        assert_eq!(dot(&x, &x), 9.0);
        assert_eq!(nrm2_sq(&x), 9.0);
        assert_eq!(dot_f32(&x, &x), 9.0);
    }

    #[test]
    fn unrolled_scal_and_nrm2_handle_every_remainder() {
        for n in [0usize, 1, 3, 4, 7, 8, 9, 16, 19] {
            let v: Vec<f32> = (0..n).map(|k| k as f32 * 0.25 - 1.0).collect();
            // scal is elementwise: must match the naive loop exactly
            let mut a = v.clone();
            scal(1.5, &mut a);
            for k in 0..n {
                assert_eq!(a[k], v[k] * 1.5, "n={n} k={k}");
            }
            // nrm2_sq re-associates in f64: tolerance, not bits
            let want: f64 = v.iter().map(|x| (*x as f64) * (*x as f64)).sum();
            assert!((nrm2_sq(&v) - want).abs() < 1e-12 * (1.0 + want), "n={n}");
        }
    }

    #[test]
    fn empty_slices_are_fine() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(nrm2_sq(&[]), 0.0);
        let mut e: [f32; 0] = [];
        axpy(1.0, &[], &mut e);
        scal(2.0, &mut e);
    }
}
