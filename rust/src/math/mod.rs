//! Native math backend: a bit-careful Rust mirror of the Layer-2 JAX model.
//!
//! Serves three roles:
//! 1. **Oracle** — integration tests assert the PJRT-executed artifacts and
//!    these routines agree to f32 tolerance, closing the
//!    `pallas == ref.py == rust == artifacts` loop.
//! 2. **Portable fallback** — experiments run without artifacts when
//!    `backend.kind = "native"`.
//! 3. **Baseline** — the §Perf comparison of PJRT dispatch overhead vs a
//!    hand-rolled hot loop.

pub mod dense;
pub mod logistic;
pub mod sparse;

pub use dense::{axpy, dot, nrm2_sq, scal};
pub use logistic::{grad_into, loss_sum, objective_batch, objective_full, sigmoid};
pub use sparse::{grad_into_csr, loss_sum_csr, objective_batch_csr, sparse_dot};
