//! Terminal convergence plots — the figures of the paper, in ASCII.
//!
//! Renders `log10(f(w) − p*)` against training time for several series
//! (RS/CS/SS), which is exactly what Figs. 1–4 plot.

use crate::metrics::Trace;

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series<'a> {
    /// Legend label (e.g. "SS").
    pub label: String,
    /// Glyph used for this series.
    pub glyph: char,
    /// The trace to plot.
    pub trace: &'a Trace,
}

/// Render series into a `width x height` character grid.
///
/// X axis: cumulative training time (seconds). Y axis: `log10(obj − p*)`,
/// clamped to a floor of 1e-15.
pub fn render(series: &[Series<'_>], p_star: f64, width: usize, height: usize) -> String {
    let width = width.max(20);
    let height = height.max(5);
    let mut pts: Vec<(usize, f64, f64)> = Vec::new(); // (series, t, logGap)
    for (si, s) in series.iter().enumerate() {
        for p in &s.trace.points {
            let gap = (p.objective - p_star).max(1e-15);
            pts.push((si, p.train_time_s, gap.log10()));
        }
    }
    if pts.is_empty() {
        return "(no data)\n".into();
    }
    let tmax = pts.iter().map(|p| p.1).fold(0.0, f64::max).max(1e-12);
    let ymin = pts.iter().map(|p| p.2).fold(f64::INFINITY, f64::min);
    let ymax = pts.iter().map(|p| p.2).fold(f64::NEG_INFINITY, f64::max);
    let yspan = (ymax - ymin).max(1e-9);

    let mut grid = vec![vec![' '; width]; height];
    for (si, t, ly) in pts {
        let col = ((t / tmax) * (width - 1) as f64).round() as usize;
        let row = (((ymax - ly) / yspan) * (height - 1) as f64).round() as usize;
        grid[row.min(height - 1)][col.min(width - 1)] = series[si].glyph;
    }

    let mut out = String::new();
    out.push_str(&format!(
        "log10(f-p*)  top={ymax:.2} bottom={ymin:.2}   (x: 0..{tmax:.3}s)\n"
    ));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    let legend: Vec<String> =
        series.iter().map(|s| format!("{}={}", s.glyph, s.label)).collect();
    out.push_str(&format!("  {}\n", legend.join("  ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series_with_legend() {
        let mut a = Trace::default();
        let mut b = Trace::default();
        for k in 0..10 {
            a.push(k, k as f64, 1.0 + 0.5f64.powi(k as i32));
            b.push(k, 2.0 * k as f64, 1.0 + 0.7f64.powi(k as i32));
        }
        let s = render(
            &[
                Series { label: "SS".into(), glyph: 's', trace: &a },
                Series { label: "RS".into(), glyph: 'r', trace: &b },
            ],
            1.0,
            60,
            12,
        );
        assert!(s.contains("s=SS"));
        assert!(s.contains("r=RS"));
        assert!(s.contains('s'));
        assert!(s.contains('r'));
        assert!(s.lines().count() >= 12);
    }

    #[test]
    fn empty_series_do_not_panic() {
        let t = Trace::default();
        let s = render(&[Series { label: "x".into(), glyph: 'x', trace: &t }], 0.0, 40, 8);
        assert_eq!(s, "(no data)\n");
    }
}
