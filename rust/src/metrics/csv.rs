//! CSV export for traces and table rows (feeds external plotting).

use std::io::Write;
use std::path::Path;

use crate::error::Result;
use crate::metrics::Trace;

/// Write a convergence trace as `epoch,train_time_s,objective`.
pub fn write_trace(path: impl AsRef<Path>, label: &str, trace: &Trace) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "# {label}")?;
    writeln!(f, "epoch,train_time_s,objective")?;
    for p in &trace.points {
        writeln!(f, "{},{:.9},{:.12}", p.epoch, p.train_time_s, p.objective)?;
    }
    Ok(())
}

/// Write generic rows with a header (used by the table harness).
pub fn write_rows(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_csv_roundtrip_by_eye() {
        let mut t = Trace::default();
        t.push(0, 0.5, 0.25);
        t.push(1, 1.0, 0.125);
        let p = std::env::temp_dir().join(format!("trace_{}.csv", std::process::id()));
        write_trace(&p, "unit", &t).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.starts_with("# unit\n"));
        assert!(body.contains("epoch,train_time_s,objective"));
        assert!(body.contains("1,1.000000000,0.125000000000"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rows_csv() {
        let p = std::env::temp_dir().join(format!("rows_{}.csv", std::process::id()));
        write_rows(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let body = std::fs::read_to_string(&p).unwrap();
        assert_eq!(body, "a,b\n1,2\n");
        std::fs::remove_file(p).ok();
    }
}
