//! Training-time decomposition (paper eq. 1):
//! `training time = time to access data + time to process data`.

use crate::storage::pagestore::IoStats;
use crate::storage::simulator::AccessCost;

/// Accumulated time breakdown for one experiment arm.
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeBreakdown {
    /// Simulated device access time (storage simulator).
    pub sim_access_s: f64,
    /// Measured host time spent assembling batches (gather/copy) — the
    /// real, non-simulated residual of the access pattern.
    pub assemble_s: f64,
    /// Measured compute time (backend calls: gradients, objectives, fused
    /// steps, line-search evaluations).
    pub compute_s: f64,
    /// Measured wall-clock of the whole training loop (sanity envelope).
    pub wall_s: f64,
    /// Device access statistics.
    pub access: AccessCost,
    /// Feature-matrix bytes physically copied when assembling batches
    /// (scattered/RS gathers). Zero for pure CS/SS runs on the zero-copy
    /// pipeline — the host-side half of the paper's access-cost story.
    pub bytes_copied: u64,
    /// Feature-matrix bytes served zero-copy as range views (CS/SS).
    pub bytes_borrowed: u64,
    /// Real file I/O of the paged (out-of-core) store for this arm —
    /// all-zero for in-core runs. Printed *next to* the simulated access
    /// cost so the modeled and the physically measured access time can be
    /// compared side by side.
    pub io: IoStats,
}

impl TimeBreakdown {
    /// The paper's "training time": access + processing.
    /// Simulated device time + measured assembly + measured compute.
    pub fn training_time_s(&self) -> f64 {
        self.sim_access_s + self.assemble_s + self.compute_s
    }

    /// Fraction of training time spent accessing data.
    pub fn access_fraction(&self) -> f64 {
        let t = self.training_time_s();
        if t <= 0.0 {
            0.0
        } else {
            (self.sim_access_s + self.assemble_s) / t
        }
    }

    /// Fraction of assembled feature bytes that had to be physically copied
    /// (0.0 for pure CS/SS on the zero-copy pipeline, 1.0 for pure RS).
    pub fn copy_fraction(&self) -> f64 {
        let total = self.bytes_copied + self.bytes_borrowed;
        if total == 0 {
            0.0
        } else {
            self.bytes_copied as f64 / total as f64
        }
    }

    /// Merge another breakdown (e.g. across epochs).
    pub fn merge(&mut self, other: &TimeBreakdown) {
        self.sim_access_s += other.sim_access_s;
        self.assemble_s += other.assemble_s;
        self.compute_s += other.compute_s;
        self.wall_s += other.wall_s;
        self.access += other.access;
        self.bytes_copied += other.bytes_copied;
        self.bytes_borrowed += other.bytes_borrowed;
        self.io += other.io;
    }
}

/// Monotonic stopwatch with f64 seconds.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Seconds since start, and restart.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.0.elapsed().as_secs_f64();
        self.0 = std::time::Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_time_sums_components() {
        let t = TimeBreakdown {
            sim_access_s: 2.0,
            assemble_s: 0.5,
            compute_s: 1.5,
            wall_s: 2.1,
            ..Default::default()
        };
        assert!((t.training_time_s() - 4.0).abs() < 1e-12);
        assert!((t.access_fraction() - 2.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = TimeBreakdown::default();
        let b = TimeBreakdown {
            sim_access_s: 1.0,
            assemble_s: 0.25,
            compute_s: 2.0,
            wall_s: 2.5,
            access: AccessCost { seeks: 3, ..Default::default() },
            bytes_copied: 100,
            bytes_borrowed: 300,
            io: IoStats { bytes_read: 64, page_faults: 2, ..Default::default() },
        };
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.access.seeks, 6);
        assert!((a.training_time_s() - 6.5).abs() < 1e-12);
        assert_eq!(a.bytes_copied, 200);
        assert_eq!(a.bytes_borrowed, 600);
        assert_eq!(a.io.bytes_read, 128);
        assert_eq!(a.io.page_faults, 4);
    }

    #[test]
    fn zero_breakdown_has_zero_fractions() {
        assert_eq!(TimeBreakdown::default().access_fraction(), 0.0);
        assert_eq!(TimeBreakdown::default().copy_fraction(), 0.0);
    }

    #[test]
    fn copy_fraction_is_copied_over_total() {
        let t = TimeBreakdown { bytes_copied: 1, bytes_borrowed: 3, ..Default::default() };
        assert!((t.copy_fraction() - 0.25).abs() < 1e-12);
        let rs = TimeBreakdown { bytes_copied: 8, bytes_borrowed: 0, ..Default::default() };
        assert_eq!(rs.copy_fraction(), 1.0);
    }

    #[test]
    fn stopwatch_measures_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let lap = sw.lap_s();
        assert!(lap >= 0.009, "lap={lap}");
        assert!(sw.elapsed_s() < lap, "restarted");
    }
}
