//! Training-time decomposition (paper eq. 1):
//! `training time = time to access data + time to process data`.

use crate::storage::simulator::AccessCost;

/// Accumulated time breakdown for one experiment arm.
#[derive(Debug, Clone, Copy, Default)]
pub struct TimeBreakdown {
    /// Simulated device access time (storage simulator).
    pub sim_access_s: f64,
    /// Measured host time spent assembling batches (gather/copy) — the
    /// real, non-simulated residual of the access pattern.
    pub assemble_s: f64,
    /// Measured compute time (backend calls: gradients, objectives, fused
    /// steps, line-search evaluations).
    pub compute_s: f64,
    /// Measured wall-clock of the whole training loop (sanity envelope).
    pub wall_s: f64,
    /// Device access statistics.
    pub access: AccessCost,
}

impl TimeBreakdown {
    /// The paper's "training time": access + processing.
    /// Simulated device time + measured assembly + measured compute.
    pub fn training_time_s(&self) -> f64 {
        self.sim_access_s + self.assemble_s + self.compute_s
    }

    /// Fraction of training time spent accessing data.
    pub fn access_fraction(&self) -> f64 {
        let t = self.training_time_s();
        if t <= 0.0 {
            0.0
        } else {
            (self.sim_access_s + self.assemble_s) / t
        }
    }

    /// Merge another breakdown (e.g. across epochs).
    pub fn merge(&mut self, other: &TimeBreakdown) {
        self.sim_access_s += other.sim_access_s;
        self.assemble_s += other.assemble_s;
        self.compute_s += other.compute_s;
        self.wall_s += other.wall_s;
        self.access += other.access;
    }
}

/// Monotonic stopwatch with f64 seconds.
#[derive(Debug)]
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Seconds since start, and restart.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.0.elapsed().as_secs_f64();
        self.0 = std::time::Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_time_sums_components() {
        let t = TimeBreakdown {
            sim_access_s: 2.0,
            assemble_s: 0.5,
            compute_s: 1.5,
            wall_s: 2.1,
            access: AccessCost::default(),
        };
        assert!((t.training_time_s() - 4.0).abs() < 1e-12);
        assert!((t.access_fraction() - 2.5 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = TimeBreakdown::default();
        let b = TimeBreakdown {
            sim_access_s: 1.0,
            assemble_s: 0.25,
            compute_s: 2.0,
            wall_s: 2.5,
            access: AccessCost { seeks: 3, ..Default::default() },
        };
        a.merge(&b);
        a.merge(&b);
        assert_eq!(a.access.seeks, 6);
        assert!((a.training_time_s() - 6.5).abs() < 1e-12);
    }

    #[test]
    fn zero_breakdown_has_zero_fraction() {
        assert_eq!(TimeBreakdown::default().access_fraction(), 0.0);
    }

    #[test]
    fn stopwatch_measures_time() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let lap = sw.lap_s();
        assert!(lap >= 0.009, "lap={lap}");
        assert!(sw.elapsed_s() < lap, "restarted");
    }
}
