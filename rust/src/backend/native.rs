//! Native Rust compute backend — `crate::math` behind the backend trait.

use crate::backend::ComputeBackend;
use crate::data::batch::BatchView;
use crate::error::Result;

/// Allocation-free native backend.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// Construct the native backend.
    pub fn new() -> Self {
        NativeBackend
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &BatchView<'_>,
        c: f32,
        out: &mut [f32],
    ) -> Result<()> {
        crate::math::grad_into(w, batch.x, batch.y, batch.cols, c, out);
        Ok(())
    }

    fn batch_obj(&mut self, w: &[f32], batch: &BatchView<'_>, c: f32) -> Result<f64> {
        Ok(crate::math::objective_batch(w, batch.x, batch.y, batch.cols, c))
    }

    fn loss_sum(&mut self, w: &[f32], batch: &BatchView<'_>) -> Result<f64> {
        Ok(crate::math::loss_sum(w, batch.x, batch.y, batch.cols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn toy(rows: usize, cols: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed_from(1);
        let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..rows)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let w: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
        (x, y, w)
    }

    #[test]
    fn matches_math_module() {
        let (x, y, w) = toy(32, 8);
        let view = BatchView { x: &x, y: &y, rows: 32, cols: 8 };
        let mut be = NativeBackend::new();
        let mut g = vec![0f32; 8];
        be.grad_into(&w, &view, 0.1, &mut g).unwrap();
        let mut want = vec![0f32; 8];
        crate::math::grad_into(&w, &x, &y, 8, 0.1, &mut want);
        assert_eq!(g, want);
        assert_eq!(
            be.batch_obj(&w, &view, 0.1).unwrap(),
            crate::math::objective_batch(&w, &x, &y, 8, 0.1)
        );
    }

    #[test]
    fn full_objective_equals_single_batch_objective() {
        let (x, y, w) = toy(100, 5);
        let ds = crate::data::dense::DenseDataset::new("t", 5, x.clone(), y.clone()).unwrap();
        let mut be = NativeBackend::new();
        let full = be.full_objective(&w, &ds, 0.2).unwrap();
        let whole = crate::math::objective_full(&w, &x, &y, 5, 0.2);
        assert!((full - whole).abs() < 1e-9, "{full} vs {whole}");
    }

    #[test]
    fn fused_unsupported() {
        let (x, y, mut w) = toy(8, 3);
        let view = BatchView { x: &x, y: &y, rows: 8, cols: 3 };
        let mut be = NativeBackend::new();
        let handled = be
            .fused(
                crate::backend::FusedStep::Mbsgd { w: &mut w, lr: 0.1 },
                &view,
                0.0,
            )
            .unwrap();
        assert!(!handled);
    }
}
