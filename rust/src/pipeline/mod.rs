//! Streaming data pipeline: bounded-channel prefetcher (reader runs ahead of
//! the trainer under backpressure) and shard splitting for the paper's
//! "parallel and distributed" extension (§5: "These sampling techniques can
//! be extended to parallel and distributed learning algorithms").

pub mod prefetch;
pub mod shard;

pub use prefetch::{PrefetchStats, PrefetchedBatch, Prefetcher};
pub use shard::{rebalance, Shard};
