//! Bounded-channel batch prefetcher.
//!
//! A reader thread walks one epoch's [`RowSelection`]s, charges the access
//! simulator, gathers rows into owned buffers, and sends them through a
//! `sync_channel(depth)` — the channel bound *is* the backpressure: the
//! reader blocks once it is `depth` batches ahead of the trainer, so memory
//! stays bounded at `depth * batch_bytes` while real gather time overlaps
//! solver compute.

use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::batch::RowSelection;
use crate::data::dense::DenseDataset;
use crate::storage::simulator::{AccessCost, AccessSimulator};

/// An owned, assembled mini-batch produced by the reader thread.
#[derive(Debug)]
pub struct PrefetchedBatch {
    /// Row-major features.
    pub x: Vec<f32>,
    /// Labels.
    pub y: Vec<f32>,
    /// Row count.
    pub rows: usize,
    /// Position of this batch within the epoch.
    pub j: usize,
    /// Simulated device cost of this fetch.
    pub sim: AccessCost,
    /// Measured host seconds spent gathering.
    pub assemble_s: f64,
}

/// Reader-side totals returned when the epoch finishes.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchStats {
    /// Total simulated access seconds.
    pub sim_access_s: f64,
    /// Total measured gather seconds.
    pub assemble_s: f64,
    /// Batches produced.
    pub batches: usize,
    /// Times the reader blocked on a full channel (backpressure events).
    pub stalls: u64,
}

/// Handle to one epoch's prefetch run.
#[derive(Debug)]
pub struct Prefetcher {
    rx: Receiver<PrefetchedBatch>,
    handle: Option<JoinHandle<(AccessSimulator, PrefetchStats)>>,
}

impl Prefetcher {
    /// Spawn the reader for `selections` over `ds`, with channel bound
    /// `depth` (≥1). The simulator is moved in and returned by [`join`] so
    /// its page-cache state persists across epochs.
    ///
    /// [`join`]: Prefetcher::join
    pub fn spawn(
        ds: Arc<DenseDataset>,
        selections: Vec<RowSelection>,
        mut sim: AccessSimulator,
        depth: usize,
    ) -> Self {
        let depth = depth.max(1);
        let (tx, rx) = sync_channel::<PrefetchedBatch>(depth);
        let handle = std::thread::spawn(move || {
            let mut stats = PrefetchStats::default();
            let cols = ds.cols();
            for (j, sel) in selections.into_iter().enumerate() {
                let sim_cost = sim.fetch(&sel);
                let t0 = std::time::Instant::now();
                let rows = sel.len();
                let mut x = Vec::with_capacity(rows * cols);
                let mut y = Vec::with_capacity(rows);
                match &sel {
                    RowSelection::Contiguous { start, end } => {
                        let (xs, ys) = ds.rows_slice(*start, *end);
                        x.extend_from_slice(xs);
                        y.extend_from_slice(ys);
                    }
                    RowSelection::Scattered(idx) => {
                        for &r in idx {
                            x.extend_from_slice(ds.row(r as usize));
                            y.push(ds.y()[r as usize]);
                        }
                    }
                }
                let assemble_s = t0.elapsed().as_secs_f64();
                stats.sim_access_s += sim_cost.time_s;
                stats.assemble_s += assemble_s;
                stats.batches += 1;
                let batch = PrefetchedBatch { x, y, rows, j, sim: sim_cost, assemble_s };
                // try_send first so we can count backpressure stalls
                match tx.try_send(batch) {
                    Ok(()) => {}
                    Err(std::sync::mpsc::TrySendError::Full(b)) => {
                        stats.stalls += 1;
                        if tx.send(b).is_err() {
                            break; // trainer dropped the receiver
                        }
                    }
                    Err(std::sync::mpsc::TrySendError::Disconnected(_)) => break,
                }
            }
            (sim, stats)
        });
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Receive the next batch (None when the epoch is exhausted).
    pub fn next_batch(&mut self) -> Option<PrefetchedBatch> {
        self.rx.recv().ok()
    }

    /// Wait for the reader and take back the simulator + stats.
    pub fn join(mut self) -> (AccessSimulator, PrefetchStats) {
        // drain anything left so the reader can finish
        while self.rx.try_recv().is_ok() {}
        drop(self.rx);
        self.handle
            .take()
            .expect("join called once")
            .join()
            .expect("prefetch thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::profile::DeviceProfile;

    fn ds(rows: usize, cols: usize) -> Arc<DenseDataset> {
        let x: Vec<f32> = (0..rows * cols).map(|v| v as f32).collect();
        let y: Vec<f32> = (0..rows).map(|r| if r % 2 == 0 { 1.0 } else { -1.0 }).collect();
        Arc::new(DenseDataset::new("t", cols, x, y).unwrap())
    }

    fn sim(ds: &DenseDataset) -> AccessSimulator {
        AccessSimulator::for_dataset(DeviceProfile::hdd(), ds, 1 << 20)
    }

    #[test]
    fn delivers_all_batches_in_order_with_correct_content() {
        let d = ds(40, 3);
        let sels: Vec<RowSelection> = (0..4)
            .map(|j| RowSelection::Contiguous { start: j * 10, end: (j + 1) * 10 })
            .collect();
        let mut pf = Prefetcher::spawn(d.clone(), sels, sim(&d), 2);
        let mut seen = 0;
        while let Some(b) = pf.next_batch() {
            assert_eq!(b.j, seen);
            assert_eq!(b.rows, 10);
            let (want_x, want_y) = d.rows_slice(b.j * 10, (b.j + 1) * 10);
            assert_eq!(b.x, want_x);
            assert_eq!(b.y, want_y);
            seen += 1;
        }
        assert_eq!(seen, 4);
        let (_, stats) = pf.join();
        assert_eq!(stats.batches, 4);
        assert!(stats.sim_access_s > 0.0);
    }

    #[test]
    fn scattered_selection_gathers() {
        let d = ds(20, 2);
        let sels = vec![RowSelection::Scattered(vec![5, 1, 9])];
        let mut pf = Prefetcher::spawn(d.clone(), sels, sim(&d), 1);
        let b = pf.next_batch().unwrap();
        assert_eq!(b.x, &[10.0, 11.0, 2.0, 3.0, 18.0, 19.0]);
        assert!(pf.next_batch().is_none());
        pf.join();
    }

    #[test]
    fn backpressure_stalls_are_counted() {
        let d = ds(1000, 4);
        let sels: Vec<RowSelection> = (0..100)
            .map(|j| RowSelection::Contiguous { start: j * 10, end: (j + 1) * 10 })
            .collect();
        let mut pf = Prefetcher::spawn(d.clone(), sels, sim(&d), 1);
        // slow consumer: force the channel to fill
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut n = 0;
        while let Some(_b) = pf.next_batch() {
            n += 1;
        }
        assert_eq!(n, 100);
        let (_, stats) = pf.join();
        assert!(stats.stalls > 0, "reader should have hit backpressure");
    }

    #[test]
    fn simulator_cache_state_survives_epochs() {
        let d = ds(100, 4);
        let sels: Vec<RowSelection> =
            vec![RowSelection::Contiguous { start: 0, end: 100 }];
        let mut pf = Prefetcher::spawn(d.clone(), sels.clone(), sim(&d), 1);
        while pf.next_batch().is_some() {}
        let (sim1, stats1) = pf.join();
        assert!(stats1.sim_access_s > 0.0);
        // epoch 2 with the same simulator: everything cached, zero cost
        let mut pf2 = Prefetcher::spawn(d, sels, sim1, 1);
        while pf2.next_batch().is_some() {}
        let (_, stats2) = pf2.join();
        assert_eq!(stats2.sim_access_s, 0.0, "cache must persist across epochs");
    }

    #[test]
    fn dropping_receiver_stops_reader() {
        let d = ds(1000, 4);
        let sels: Vec<RowSelection> = (0..100)
            .map(|j| RowSelection::Contiguous { start: j * 10, end: (j + 1) * 10 })
            .collect();
        let pf = Prefetcher::spawn(d, sels, sim(&ds(1000, 4)), 1);
        // join drains + drops; reader must exit promptly without panic
        let (_, stats) = pf.join();
        assert!(stats.batches <= 100);
    }
}
