//! In-tree micro-benchmark timing (offline build: no criterion).
//!
//! Median-of-samples methodology: warmup runs, then `samples` timed runs of
//! `iters` iterations each; reports median/mean/min per iteration. Results
//! print in a fixed-width table consumed by EXPERIMENTS.md §Perf.

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark id.
    pub name: String,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Fastest sample, seconds per iteration.
    pub min_s: f64,
    /// Iterations per timed sample.
    pub iters: usize,
}

impl BenchResult {
    /// Render one table row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}",
            self.name,
            human(self.median_s),
            human(self.mean_s),
            human(self.min_s)
        )
    }
}

/// Pretty seconds.
pub fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Table header matching [`BenchResult::row`].
pub fn header() -> String {
    format!(
        "{:<44} {:>12} {:>12} {:>12}",
        "benchmark", "median/iter", "mean/iter", "min/iter"
    )
}

/// Run one benchmark: `warmup` untimed runs, then `samples` samples of
/// `iters` iterations.
pub fn bench(name: &str, warmup: usize, samples: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = std::time::Instant::now();
        for _ in 0..iters.max(1) {
            f();
        }
        per_iter.push(t0.elapsed().as_secs_f64() / iters.max(1) as f64);
    }
    per_iter.sort_by(|a, b| a.total_cmp(b));
    let median_s = per_iter[per_iter.len() / 2];
    let mean_s = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let min_s = per_iter[0];
    BenchResult { name: name.into(), median_s, mean_s, min_s, iters }
}

/// Epochs knob shared by the table/figure benches
/// (`SAMPLEX_BENCH_EPOCHS`, default 30 — the paper's setting).
pub fn bench_epochs() -> usize {
    std::env::var("SAMPLEX_BENCH_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut acc = 0u64;
        let r = bench("spin", 1, 3, 10, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert!(r.median_s >= 0.0);
        assert!(r.min_s <= r.median_s);
        assert!(r.row().contains("spin"));
        assert!(acc > 0 || acc == 0); // keep the side effect alive
    }

    #[test]
    fn human_units() {
        assert!(human(2.5).ends_with('s'));
        assert!(human(2.5e-3).ends_with("ms"));
        assert!(human(2.5e-6).ends_with("us"));
        assert!(human(2.5e-9).ends_with("ns"));
    }

    #[test]
    fn epochs_default_is_paper_setting() {
        std::env::remove_var("SAMPLEX_BENCH_EPOCHS");
        assert_eq!(bench_epochs(), 30);
    }
}
