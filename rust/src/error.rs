//! Crate-wide error type.

/// Unified error for all samplex subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// I/O failures (dataset files, artifact files, reports).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// XLA / PJRT runtime failures.
    #[error("xla error: {0}")]
    Xla(String),

    /// Malformed dataset file (LIBSVM text or .sxb binary).
    #[error("dataset parse error at line {line}: {msg}")]
    DatasetParse { line: usize, msg: String },

    /// Configuration validation failure.
    #[error("config error: {0}")]
    Config(String),

    /// Manifest / artifact bookkeeping failure.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Shape mismatch between coordinator and compiled executable.
    #[error("shape mismatch: expected {expected}, got {got} ({context})")]
    ShapeMismatch {
        expected: String,
        got: String,
        context: String,
    },

    /// Anything else.
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
