//! Paper Table 4 — covtype.binary (scaled stand-in `covtype-mini`,
//! DESIGN.md §3): same grid as Table 2.
//!
//! ```bash
//! cargo bench --bench table_covtype
//! ```

#[path = "common.rs"]
mod common;

fn main() {
    common::run_table_bench("covtype-mini");
}
