//! Micro-benchmarks for the §Perf pass: per-component hot-path costs.
//!
//! * sampler epoch generation (RS/CS/SS)
//! * storage-simulator fetch costing (contiguous vs scattered)
//! * LRU cache touch throughput
//! * batch assembly: borrow (CS/SS) vs gather (RS)
//! * native gradient (several shapes)
//! * PJRT gradient + fused step dispatch (when artifacts exist)
//! * prefetch pipeline end-to-end epoch
//! * sparse (CSR) pipeline: CS vs RS epochs on a ~0.1%-density synthetic,
//!   with borrowed/copied byte traffic next to the dense numbers
//!
//! ```bash
//! cargo bench --bench micro
//! ```

use samplex::backend::{ComputeBackend, FusedStep, NativeBackend, PjrtBackend};
use samplex::bench_harness::timing::{bench, header};
use samplex::data::batch::{BatchAssembler, BatchView, RowSelection};
use samplex::data::dense::DenseDataset;
use samplex::data::synth::SparseSynthSpec;
use samplex::data::Dataset;
use samplex::rng::Rng;
use samplex::sampling::{Sampler, SamplingKind};
use samplex::storage::cache::LruCache;
use samplex::storage::profile::DeviceProfile;
use samplex::storage::simulator::AccessSimulator;

fn dense_parts(rows: usize, cols: usize) -> DenseDataset {
    let mut rng = Rng::seed_from(1);
    let x: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..rows)
        .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
        .collect();
    DenseDataset::new("bench", cols, x, y).unwrap()
}

fn dataset(rows: usize, cols: usize) -> Dataset {
    dense_parts(rows, cols).into()
}

fn main() {
    println!("{}", header());
    let mut results = Vec::new();

    // --- samplers ---------------------------------------------------------
    let (rows, batch) = (120_000, 500);
    for kind in [SamplingKind::Rs, SamplingKind::Cs, SamplingKind::Ss] {
        let mut s: Box<dyn Sampler> = kind.build(rows, batch, 7, None).unwrap();
        let mut e = 0usize;
        results.push(bench(
            &format!("sampler/{}/epoch 120k rows b=500", kind.label()),
            2,
            7,
            5,
            || {
                e += 1;
                std::hint::black_box(s.epoch(e));
            },
        ));
        println!("{}", results.last().unwrap().row());
    }

    // --- storage simulator -------------------------------------------------
    let ds = dataset(50_000, 28);
    let mut sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &ds, 0);
    let contiguous = RowSelection::Contiguous { start: 1000, end: 1500 };
    results.push(bench("sim/fetch contiguous 500 rows", 5, 9, 200, || {
        std::hint::black_box(sim.fetch(&contiguous));
    }));
    println!("{}", results.last().unwrap().row());

    let mut rng = Rng::seed_from(3);
    let scattered =
        RowSelection::Scattered((0..500).map(|_| rng.below(50_000) as u32).collect());
    results.push(bench("sim/fetch scattered 500 rows", 5, 9, 200, || {
        std::hint::black_box(sim.fetch(&scattered));
    }));
    println!("{}", results.last().unwrap().row());

    // --- LRU ---------------------------------------------------------------
    let mut lru = LruCache::new(4096);
    let mut key = 0u64;
    results.push(bench("cache/lru touch (miss-heavy)", 3, 9, 100_000, || {
        key = key.wrapping_add(1) % 16_384;
        std::hint::black_box(lru.touch(key));
    }));
    println!("{}", results.last().unwrap().row());

    // --- batch assembly ------------------------------------------------------
    let mut asm = BatchAssembler::new();
    results.push(bench("assemble/borrow contiguous b=500 n=28", 5, 9, 2000, || {
        std::hint::black_box(asm.assemble(&ds, &contiguous).unwrap());
    }));
    println!("{}", results.last().unwrap().row());
    results.push(bench("assemble/gather scattered b=500 n=28", 5, 9, 500, || {
        std::hint::black_box(asm.assemble(&ds, &scattered).unwrap());
    }));
    println!("{}", results.last().unwrap().row());

    // --- native math ---------------------------------------------------------
    for (b, n) in [(200usize, 28usize), (1000, 28), (1000, 256)] {
        let dsn = dense_parts(b, n);
        let w = vec![0.1f32; n];
        let mut g = vec![0f32; n];
        let mut be = NativeBackend::new();
        let view = BatchView::dense(dsn.x(), dsn.y(), n);
        results.push(bench(&format!("native/grad b={b} n={n}"), 3, 9, 200, || {
            be.grad_into(&w, &view, 1e-4, &mut g).unwrap();
            std::hint::black_box(&g);
        }));
        println!("{}", results.last().unwrap().row());
    }

    // --- compute plane: pooled full-dataset sweeps ----------------------------
    {
        let full = dataset(120_000, 28);
        let w = vec![0.05f32; 28];
        let mut g = vec![0f32; 28];
        let mut scratch = samplex::math::chunked::GradScratch::default();
        let mut be = NativeBackend::new();
        for threads in [1usize, samplex::runtime::pool::parallelism()] {
            samplex::runtime::pool::set_parallelism(threads);
            results.push(bench(&format!("pool/full objective 120k t={threads}"), 1, 5, 2, || {
                std::hint::black_box(be.full_objective(&w, &full, 1e-4).unwrap());
            }));
            println!("{}", results.last().unwrap().row());
            results.push(bench(&format!("pool/full gradient 120k t={threads}"), 1, 5, 2, || {
                samplex::math::chunked::full_grad_into(&w, &full, 1e-4, &mut g, &mut scratch).unwrap();
                std::hint::black_box(&g);
            }));
            println!("{}", results.last().unwrap().row());
            samplex::runtime::pool::set_parallelism(0);
        }
    }

    // --- PJRT dispatch --------------------------------------------------------
    let artifacts = std::path::Path::new("artifacts").join("manifest.tsv");
    if artifacts.is_file() {
        for (b, n) in [(200usize, 28usize), (1000, 28), (1000, 256)] {
            let dsn = dense_parts(b, n);
            let mut pjrt = PjrtBackend::new("artifacts", n, b).unwrap();
            let w = vec![0.1f32; n];
            let mut g = vec![0f32; n];
            let view = BatchView::dense(dsn.x(), dsn.y(), n);
            results.push(bench(&format!("pjrt/grad b={b} n={n}"), 3, 9, 50, || {
                pjrt.grad_into(&w, &view, 1e-4, &mut g).unwrap();
                std::hint::black_box(&g);
            }));
            println!("{}", results.last().unwrap().row());

            let mut wmut = vec![0.1f32; n];
            results.push(bench(&format!("pjrt/fused mbsgd b={b} n={n}"), 3, 9, 50, || {
                pjrt.fused(FusedStep::Mbsgd { w: &mut wmut, lr: 1e-3 }, &view, 1e-4)
                    .unwrap();
            }));
            println!("{}", results.last().unwrap().row());
        }
    } else {
        eprintln!("(skipping pjrt benches: run `make artifacts`)");
    }

    // --- prefetch pipeline ------------------------------------------------------
    let big = std::sync::Arc::new(dataset(50_000, 28));
    results.push(bench("pipeline/prefetch epoch 100 batches (spawn+run)", 1, 5, 1, || {
        let sels: Vec<RowSelection> = (0..100)
            .map(|j| RowSelection::Contiguous { start: j * 500, end: (j + 1) * 500 })
            .collect();
        let sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &big, 0);
        let mut pf = samplex::pipeline::prefetch::Prefetcher::spawn(big.clone(), sim, 2);
        pf.start_epoch(sels);
        while let Some(b) = pf.next_batch().unwrap() {
            std::hint::black_box(b.view(28).rows());
        }
        pf.finish();
    }));
    println!("{}", results.last().unwrap().row());

    // persistent reader: epoch turnaround without a thread spawn
    {
        let sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &big, 0);
        let mut pf = samplex::pipeline::prefetch::Prefetcher::spawn(big.clone(), sim, 2);
        results.push(bench("pipeline/prefetch epoch 100 batches (persistent)", 1, 5, 1, || {
            let sels: Vec<RowSelection> = (0..100)
                .map(|j| RowSelection::Contiguous { start: j * 500, end: (j + 1) * 500 })
                .collect();
            pf.start_epoch(sels);
            while let Some(b) = pf.next_batch().unwrap() {
                std::hint::black_box(b.view(28).rows());
            }
        }));
        println!("{}", results.last().unwrap().row());
        pf.finish();
    }

    // --- copy traffic by sampling technique -------------------------------------
    // The zero-copy acceptance check: contiguous CS/SS epochs must report
    // bytes_copied == 0 (range views into the dataset), while RS pays a real
    // gather for every batch.
    println!("\ncopy traffic per epoch (dense 50k rows x 28 cols, batch 500):");
    for kind in [SamplingKind::Rs, SamplingKind::Cs, SamplingKind::Ss] {
        let mut s: Box<dyn Sampler> = kind.build(50_000, 500, 7, None).unwrap();
        let sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &big, 0);
        let mut pf = samplex::pipeline::prefetch::Prefetcher::spawn(big.clone(), sim, 2);
        pf.start_epoch(s.epoch(0));
        while let Some(b) = pf.next_batch().unwrap() {
            std::hint::black_box(b.view(28).rows());
        }
        let es = pf.last_epoch_stats();
        pf.finish();
        println!(
            "  {:<5} bytes_copied={:>12}  bytes_borrowed={:>12}  stalls={}",
            kind.label(),
            es.bytes_copied,
            es.bytes_borrowed,
            es.stalls
        );
    }

    // --- sparse (CSR) pipeline ----------------------------------------------
    // ~0.1% density: 20k rows x 100k cols, ~100 nnz/row. CS borrows all
    // three CSR slices zero-copy; RS gathers value + index bytes per batch.
    let sparse: std::sync::Arc<Dataset> = std::sync::Arc::new(
        samplex::data::synth::generate_csr(
            &SparseSynthSpec {
                name: "bench-sparse",
                rows: 20_000,
                cols: 100_000,
                nnz_per_row: 100,
                flip_prob: 0.02,
                margin_noise: 0.2,
                pos_fraction: 0.5,
            },
            7,
        )
        .unwrap()
        .into(),
    );
    println!(
        "\nsparse pipeline (CSR 20k rows x 100k cols, {} nnz = {:.3}% dense, batch 500):",
        sparse.nnz(),
        100.0 * sparse.nnz() as f64 / (20_000f64 * 100_000.0)
    );
    for kind in [SamplingKind::Cs, SamplingKind::Rs] {
        let mut sampler: Box<dyn Sampler> = kind.build(20_000, 500, 7, None).unwrap();
        let mut copied = 0u64;
        let mut borrowed = 0u64;
        let label = format!("pipeline/sparse {} epoch 40 batches", kind.label());
        {
            let sim = AccessSimulator::for_dataset(DeviceProfile::hdd(), &sparse, 0);
            let mut pf = samplex::pipeline::prefetch::Prefetcher::spawn(sparse.clone(), sim, 2);
            let mut e = 0usize;
            results.push(bench(&label, 1, 5, 1, || {
                e += 1;
                pf.start_epoch(sampler.epoch(e));
                while let Some(b) = pf.next_batch().unwrap() {
                    std::hint::black_box(b.view(100_000).rows());
                }
                let es = pf.last_epoch_stats();
                copied = es.bytes_copied;
                borrowed = es.bytes_borrowed;
            }));
            println!("{}", results.last().unwrap().row());
            pf.finish();
        }
        println!(
            "  {:<5} bytes_copied={:>12}  bytes_borrowed={:>12}",
            kind.label(),
            copied,
            borrowed
        );
    }

    // --- paged out-of-core store -------------------------------------------
    // Real file I/O: CS sweeps fault maximal page runs with one sequential
    // read each; RS faults pages individually. At a 25% budget the gap is
    // the paper's contiguous-vs-dispersed claim on actual syscalls.
    {
        let dir = std::env::temp_dir().join(format!("samplex_micro_paged_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.sxb");
        big.as_dense().unwrap().save(&path).unwrap();
        let file_bytes = big.file_bytes();
        println!(
            "\npaged out-of-core (dense 50k x 28, 64 KiB pages, budget 25% of {:.1} MiB):",
            file_bytes as f64 / (1024.0 * 1024.0)
        );
        for kind in [SamplingKind::Cs, SamplingKind::Rs] {
            let paged: Dataset =
                samplex::data::PagedDataset::open(&path, file_bytes / 4, 64 * 1024)
                    .unwrap()
                    .into();
            let mut sampler: Box<dyn Sampler> = kind.build(50_000, 500, 7, None).unwrap();
            let mut asm = BatchAssembler::new();
            let mut e = 0usize;
            results.push(bench(&format!("paged/{} epoch 100 batches", kind.label()), 1, 5, 1, || {
                e += 1;
                for sel in sampler.epoch(e) {
                    std::hint::black_box(asm.assemble(&paged, &sel).unwrap().rows());
                }
            }));
            println!("{}", results.last().unwrap().row());
            let io = paged.io_stats();
            println!(
                "  {:<5} faults={:<8} reads={:<7} bytes_read={:<12} amp={:<6.2} {:.1} MB/s",
                kind.label(),
                io.page_faults,
                io.read_calls,
                io.bytes_read,
                io.read_amplification(),
                io.mb_per_s()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    println!("\n(perf targets + before/after log: EXPERIMENTS.md §Perf)");
}
