//! Shared driver for the paper-table benches (tables 2–4).
//!
//! Each bench regenerates one paper table end-to-end: the full
//! 5 solvers × {RS,CS,SS} × {200,1000} × {const,LS} grid at
//! `SAMPLEX_BENCH_EPOCHS` epochs (default 30, the paper's setting), then
//! prints the table, the speedup summary, and wall-clock accounting.

use samplex::bench_harness::{render_table, run_table, speedup_summary, timing};
use samplex::config::GridConfig;

/// Run one paper table; `fast_solvers=None` keeps the full five-solver grid.
pub fn run_table_bench(dataset: &str) {
    let epochs = timing::bench_epochs();
    eprintln!("== table bench: {dataset}, {epochs} epochs ==");
    std::fs::create_dir_all("data").ok();
    let ds = samplex::data::registry::resolve(dataset, "data", 42)
        .expect("dataset resolution");
    eprintln!("   {} rows x {} cols", ds.rows(), ds.cols());

    let mut grid = GridConfig::paper_table(dataset);
    grid.base.epochs = epochs;

    let wall = std::time::Instant::now();
    let mut done = 0usize;
    let mut progress = |r: &samplex::train::TrainReport| {
        done += 1;
        eprintln!("   [{done:>2}/60] {}", r.summary());
    };
    let rows = run_table(&grid, &ds, Some(&mut progress)).expect("table run");
    let wall_s = wall.elapsed().as_secs_f64();

    println!("{}", render_table(dataset, epochs, &rows));
    println!("{}", speedup_summary(&rows));
    println!("bench wall-clock: {:.1}s for {} arms", wall_s, rows.len());
}
