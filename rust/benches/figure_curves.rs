//! Paper Figures 1–4 — convergence curves `f(w) − p*` vs training time for
//! RS/CS/SS across the eight datasets (figure pairs per the paper):
//!
//! * Fig. 1: susy-mini, rcv1-mini
//! * Fig. 2: ijcnn1-mini, protein-mini
//! * Fig. 3: higgs-mini, sensit-mini
//! * Fig. 4: mnist-mini, covtype-mini
//!
//! For each dataset this runs the paper's figure grid (5 solvers ×
//! batch {500,1000} × {const,LS} × {RS,CS,SS}) at `SAMPLEX_BENCH_EPOCHS`
//! epochs, prints the empirical linear-rate fits (Theorem 1 check) and a
//! compact table of series endpoints, and drops per-series CSVs under
//! `bench_out/figures/`.
//!
//! ```bash
//! cargo bench --bench figure_curves                       # all 4 figures
//! SAMPLEX_FIGURE=1 cargo bench --bench figure_curves     # one figure
//! SAMPLEX_FIGURE_SOLVER=mbsgd ...                         # restrict solver
//! ```

use samplex::backend::NativeBackend;
use samplex::bench_harness::{run_figure, timing};
use samplex::config::GridConfig;
use samplex::sampling::SamplingKind;
use samplex::solvers::SolverKind;
use samplex::train::estimate_optimum;

const FIGURES: &[(usize, [&str; 2])] = &[
    (1, ["susy-mini", "rcv1-mini"]),
    (2, ["ijcnn1-mini", "protein-mini"]),
    (3, ["higgs-mini", "sensit-mini"]),
    (4, ["mnist-mini", "covtype-mini"]),
];

fn main() {
    let epochs = timing::bench_epochs();
    let only: Option<usize> = std::env::var("SAMPLEX_FIGURE").ok().and_then(|s| s.parse().ok());
    let solver: Option<SolverKind> = std::env::var("SAMPLEX_FIGURE_SOLVER")
        .ok()
        .map(|s| SolverKind::parse(&s).expect("SAMPLEX_FIGURE_SOLVER"));
    std::fs::create_dir_all("data").ok();
    std::fs::create_dir_all("bench_out/figures").ok();

    for (fig, datasets) in FIGURES {
        if let Some(f) = only {
            if f != *fig {
                continue;
            }
        }
        for dataset in datasets {
            run_one(*fig, dataset, epochs, solver);
        }
    }
}

fn run_one(fig: usize, dataset: &str, epochs: usize, solver: Option<SolverKind>) {
    eprintln!("== figure {fig} bench: {dataset}, {epochs} epochs ==");
    let ds = match samplex::data::registry::resolve(dataset, "data", 42) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("   skipping {dataset}: {e}");
            return;
        }
    };
    let mut grid = GridConfig::paper_figure(dataset);
    grid.base.epochs = epochs;
    if let Some(s) = solver {
        grid.solvers = vec![s];
    }
    let c = samplex::train::reg_for(&grid.base);
    let mut be = NativeBackend::new();
    let p_star = estimate_optimum(&mut be, &ds, c, 2000).expect("p*");
    eprintln!("   p* = {p_star:.12}");

    let wall = std::time::Instant::now();
    let mut done = 0usize;
    let total = grid.arms().len();
    let mut progress = |r: &samplex::train::TrainReport| {
        done += 1;
        eprintln!("   [{done:>3}/{total}] {}", r.summary());
    };
    let series = run_figure(&grid, &ds, p_star, Some(&mut progress)).expect("figure run");

    println!("\nFigure {fig} — {dataset} (p* = {p_star:.10}, {epochs} epochs)");
    println!(
        "{:<38} {:>10} {:>14} {:>14} {:>12}",
        "series", "time_s", "final f-p*", "start f-p*", "rate/epoch"
    );
    for s in &series {
        let first = s.trace.points.first().unwrap();
        let last = s.trace.points.last().unwrap();
        println!(
            "{:<38} {:>10.4} {:>14.3e} {:>14.3e} {:>12}",
            s.label,
            last.train_time_s,
            (last.objective - p_star).max(0.0),
            (first.objective - p_star).max(0.0),
            s.rate.map(|r| format!("{r:+.4}")).unwrap_or_else(|| "-".into()),
        );
        let path = format!("bench_out/figures/{}.csv", s.label);
        samplex::metrics::csv::write_trace(&path, &s.label, &s.trace).ok();
    }

    // the figure's visual claim, condensed: time for RS vs CS vs SS to reach
    // the RS arm's final gap
    summarize_crossover(&series, p_star);
    println!("figure bench wall-clock: {:.1}s", wall.elapsed().as_secs_f64());
}

/// For each (solver,batch,step) setting: when did CS/SS reach the objective
/// RS only reached at its final time? (the "who wins and by how much" shape)
fn summarize_crossover(series: &[samplex::bench_harness::FigureSeries], _p_star: f64) {
    use std::collections::BTreeMap;
    let mut by_setting: BTreeMap<String, Vec<&samplex::bench_harness::FigureSeries>> =
        BTreeMap::new();
    for s in series {
        let setting = s.label.replace(&format!("-{}-", s.sampling.label()), "-*-");
        by_setting.entry(setting).or_default().push(s);
    }
    println!("\ntime-to-RS-final-objective (smaller is better):");
    for (setting, group) in by_setting {
        let Some(rs) = group.iter().find(|s| s.sampling == SamplingKind::Rs) else {
            continue;
        };
        let target = rs.trace.points.last().unwrap().objective;
        let rs_time = rs.trace.points.last().unwrap().train_time_s;
        let mut parts = vec![format!("RS {:.3}s", rs_time)];
        for s in &group {
            if s.sampling == SamplingKind::Rs {
                continue;
            }
            let t = s
                .trace
                .points
                .iter()
                .find(|p| p.objective <= target)
                .map(|p| p.train_time_s);
            match t {
                Some(t) => parts.push(format!(
                    "{} {:.3}s ({:.1}x)",
                    s.sampling.label(),
                    t,
                    rs_time / t.max(1e-12)
                )),
                None => parts.push(format!("{} n/a", s.sampling.label())),
            }
        }
        println!("  {:<36} {}", setting, parts.join("  "));
    }
}
