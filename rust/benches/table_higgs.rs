//! Paper Table 2 — HIGGS (scaled stand-in `higgs-mini`, DESIGN.md §3):
//! training time and objective after 30 epochs for
//! SAG/SAGA/SVRG/SAAG-II/MBSGD × {RS,CS,SS} × batch {200,1000} ×
//! {constant step, line search}.
//!
//! ```bash
//! cargo bench --bench table_higgs
//! SAMPLEX_BENCH_EPOCHS=10 cargo bench --bench table_higgs   # faster pass
//! ```

#[path = "common.rs"]
mod common;

fn main() {
    common::run_table_bench("higgs-mini");
}
