//! Paper Table 3 — SUSY (scaled stand-in `susy-mini`, DESIGN.md §3):
//! same grid as Table 2.
//!
//! ```bash
//! cargo bench --bench table_susy
//! ```

#[path = "common.rs"]
mod common;

fn main() {
    common::run_table_bench("susy-mini");
}
